//! HTTP endpoints of the mining service — thin adapters over
//! [`crate::api`].
//!
//! Each endpoint deserializes its body into the corresponding typed
//! request, validates it into a plan, and executes it on the worker's
//! [`Session`]; replies are the typed reply's `to_json()` bytes. The
//! request/reply schemas live with the types:
//!
//! | endpoint          | request type                      | reply type |
//! |-------------------|-----------------------------------|------------|
//! | `GET /models`     | —                                 | [`crate::api::ModelsReply`] |
//! | `POST /workloads` | a workload spec document          | [`crate::api::WorkloadReply`] |
//! | `POST /search`    | [`crate::api::SearchRequest`]     | [`crate::api::SearchReply`] (coalesced + cached) |
//! | `POST /evaluate`  | [`crate::api::EvaluateRequest`]   | [`crate::api::EvaluateReply`] |
//! | `POST /common`    | [`crate::api::CommonRequest`]     | [`crate::api::CommonReply`] |
//! | `POST /global`    | [`crate::api::GlobalRequest`]     | [`crate::api::GlobalReply`] |
//! | `POST /cluster`   | [`crate::api::ClusterRequest`]    | [`crate::api::ClusterReply`] (coalesced + cached) |
//! | `POST /jobs`      | [`crate::api::JobRequest`]        | [`crate::api::JobReply`] (202; async via [`crate::jobs`]) |
//! | `GET /jobs`       | —                                 | [`crate::api::JobListReply`] |
//! | `GET /jobs/:id`   | —                                 | [`crate::api::JobReply`] |
//! | `GET /jobs/:id/events` | —                            | SSE stream (chunked `text/event-stream`) |
//! | `GET /jobs/:id/reply`  | —                            | the stored reply, byte-identical to the sync endpoint's |
//! | `DELETE /jobs/:id`| —                                 | [`crate::api::JobReply`] (cooperative cancel) |
//! | `GET /db/export`  | —                                 | design-DB JSONL snapshot |
//! | `POST /db/import` | a design-DB JSONL export          | [`crate::api::DbImportReply`] |
//! | `GET /status`     | —                                 | [`crate::api::StatusReply`] |
//! | `GET /metrics`    | —                                 | Prometheus text exposition ([`crate::telemetry::registry`]) |
//! | `GET /profile`    | `?seconds=N&hz=M`                 | collapsed-stack span profile of the next N seconds (text) |
//!
//! Every response carries an `X-Wham-Request-Id` header with a
//! server-minted correlation id; the id is bound to the handling thread
//! as a [`crate::telemetry::log::CorrScope`], so the access log, any
//! job the request submits (WAL record, SSE frames, worker log lines),
//! and the 202 body all carry the same id.
//! `POST /workloads` validates and registers a declarative spec
//! ([`crate::workload`]); the name is then mineable by every other
//! endpoint, with design points cached under the spec's graph
//! fingerprint exactly like builtins.
//!
//! [`ApiError`] kinds map to HTTP statuses (400/404/500); `/search`,
//! `/common`, `/global`, and `/cluster` coalesce identical in-flight
//! requests by the plan's canonical coalescing key
//! ([`crate::api::plan`]).

use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::reply::{
    AlertStatus, CoalescerCounters, DbCounters, EndpointStat, JobsCounters, PerfCounters,
    SearchCounters,
};
use crate::api::{
    ApiError, ClusterRequest, CommonRequest, DbImportReply, EvaluateRequest, FromJson,
    GlobalRequest, JobListReply, JobRequest, NullSink, SearchRequest, Session, StatusReply, ToJson,
    WorkloadReply,
};
use crate::coordinator::{make_backend, BackendChoice};
use crate::cost::native::NativeCost;
use crate::jobs::{sse_frame, JobManager};
use crate::service::cache::DesignDb;
use crate::service::http::{Handler, Request, Response};
use crate::service::queue::Coalescer;
use crate::telemetry::log::{self, CorrScope};
use crate::telemetry::tsdb::{AlertEngine, AlertExpr, AlertRule, Tsdb, TsdbOptions};
use crate::telemetry::{Collect, Sample};

/// Mint a process-unique request correlation id (`r-<salt>-<seq>`); the
/// salt distinguishes restarts in interleaved logs, like the job ids.
fn mint_corr() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(1);
    static SALT: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
    let salt = *SALT.get_or_init(|| {
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0)
            & 0xffff
    });
    format!("r-{salt:x}-{:04x}", SEQ.fetch_add(1, Ordering::Relaxed))
}

/// Sliding-window latency recorder for one endpoint: a ring of the most
/// recent [`LatencyRing::CAP`] request walls (microseconds), enough for
/// p50/p95 without unbounded memory or a histogram dependency.
pub struct LatencyRing {
    name: &'static str,
    count: AtomicU64,
    samples: std::sync::Mutex<Vec<u32>>,
}

impl LatencyRing {
    const CAP: usize = 512;

    fn new(name: &'static str) -> Self {
        Self { name, count: AtomicU64::new(0), samples: std::sync::Mutex::new(Vec::new()) }
    }

    /// Record one request's wall clock.
    pub fn note(&self, wall: std::time::Duration) {
        let v = wall.as_micros().min(u128::from(u32::MAX)) as u32;
        let mut s = self.samples.lock().unwrap();
        // Ticket taken under the lock so the slot index stays consistent
        // with the vec length during warm-up and wrap-around.
        let n = self.count.fetch_add(1, Ordering::Relaxed) as usize;
        if s.len() < Self::CAP {
            s.push(v);
        } else {
            s[n % Self::CAP] = v;
        }
    }

    /// Digest over the current window; `None` before the first request.
    pub fn stat(&self) -> Option<EndpointStat> {
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return None;
        }
        let mut s = self.samples.lock().unwrap().clone();
        if s.is_empty() {
            return None;
        }
        s.sort_unstable();
        let pick = |q: f64| s[((s.len() - 1) as f64 * q).round() as usize] as f64 / 1e3;
        Some(EndpointStat {
            endpoint: self.name.to_string(),
            count,
            p50_ms: pick(0.5),
            p95_ms: pick(0.95),
        })
    }
}

/// Shared state of one running service.
pub struct ServiceState {
    pub db: Arc<DesignDb>,
    /// The async job tier behind `POST /jobs`.
    pub jobs: Arc<JobManager>,
    pub coalescer: Coalescer,
    pub backend_choice: BackendChoice,
    pub workers: usize,
    pub started: Instant,
    // Counters surfaced by `/status`.
    pub requests: AtomicU64,
    pub search_requests: AtomicU64,
    /// `/search` leader computations that ran at least one scheduler eval.
    pub cold_searches: AtomicU64,
    /// `/search` leader computations answered entirely from the database.
    pub warm_searches: AtomicU64,
    /// Scheduler invocations across all leader computations.
    pub scheduler_evals_total: AtomicU64,
    /// Responses answered with a 5xx status (alert-rule input).
    pub responses_5xx: AtomicU64,
    /// Per-endpoint latency windows (perf observability — `/status`).
    pub latency: Vec<LatencyRing>,
    /// Bounded metrics history behind `/metrics/history` + `/dashboard`.
    pub tsdb: Arc<Tsdb>,
    /// The alert engine (evaluated by the scraper thread).
    pub alerts: Arc<AlertEngine>,
}

/// The default alert rules of one service instance. Thresholds are
/// deliberately conservative — a firing rule should always be worth an
/// operator's glance.
fn default_alert_rules(queue_capacity: usize) -> Vec<AlertRule> {
    vec![
        AlertRule {
            name: "job-queue-pressure".into(),
            describe: format!(
                "job queue depth at ≥80% of its {queue_capacity}-slot capacity"
            ),
            expr: AlertExpr::GaugeAbove {
                series: "wham_jobs_queue_depth".into(),
                threshold: (queue_capacity as f64 * 0.8) - 0.5,
            },
            fire_after: 2,
            resolve_after: 2,
        },
        AlertRule {
            name: "http-5xx".into(),
            describe: "sustained 5xx responses (>0.2/s)".into(),
            expr: AlertExpr::RateAbove {
                series: "wham_http_responses_5xx_total".into(),
                per_sec: 0.2,
            },
            fire_after: 2,
            resolve_after: 3,
        },
        AlertRule {
            name: "scheduler-evals-stall".into(),
            describe: "scheduler evals/sec near zero while a search is in flight".into(),
            expr: AlertExpr::RateBelowWhile {
                series: "wham_scheduler_evals_total".into(),
                per_sec: 1.0,
                gate: "wham_coalescer_in_flight".into(),
                gate_above: 0.0,
            },
            fire_after: 5,
            resolve_after: 2,
        },
        AlertRule {
            name: "jobs-wal-growth".into(),
            describe: "jobs WAL growing faster than 1 MiB/s (checkpointing falling behind)"
                .into(),
            expr: AlertExpr::RateAbove {
                series: "wham_jobs_wal_bytes".into(),
                per_sec: 1024.0 * 1024.0,
            },
            fire_after: 3,
            resolve_after: 3,
        },
    ]
}

impl ServiceState {
    pub fn new(
        db: Arc<DesignDb>,
        backend_choice: BackendChoice,
        workers: usize,
        jobs: Arc<JobManager>,
        tsdb_opts: TsdbOptions,
    ) -> Self {
        let alerts = Arc::new(AlertEngine::new(default_alert_rules(jobs.queue_capacity())));
        Self {
            db,
            jobs,
            coalescer: Coalescer::new(),
            backend_choice,
            workers,
            started: Instant::now(),
            requests: AtomicU64::new(0),
            search_requests: AtomicU64::new(0),
            cold_searches: AtomicU64::new(0),
            warm_searches: AtomicU64::new(0),
            scheduler_evals_total: AtomicU64::new(0),
            responses_5xx: AtomicU64::new(0),
            latency: [
                "/models", "/status", "/search", "/evaluate", "/common", "/global", "/cluster",
                "/workloads", "/metrics", "/jobs", "/db", "/profile", "/dashboard",
                "/metrics/history",
            ]
            .into_iter()
            .map(LatencyRing::new)
            .collect(),
            tsdb: Arc::new(Tsdb::new(tsdb_opts)),
            alerts,
        }
    }

    /// Snapshot of the service counters as the typed `/status` reply.
    pub fn status(&self) -> StatusReply {
        let db = self.db.stats();
        let probes = db.hits + db.misses;
        let perf = PerfCounters {
            backend_rows_total: crate::cost::backend_rows_total(),
            scheduler_evals_total: crate::sched::evals_total(),
            cluster_sim_events_total: crate::cluster::events_total(),
            db_hit_rate: if probes == 0 { 0.0 } else { db.hits as f64 / probes as f64 },
            endpoints: self.latency.iter().filter_map(LatencyRing::stat).collect(),
        };
        let jc = self.jobs.counts();
        let js = self.jobs.stats();
        let jobs = JobsCounters {
            queued: jc.queued,
            running: jc.running,
            done: jc.done,
            failed: jc.failed,
            cancelled: jc.cancelled,
            queue_depth: self.jobs.queue_depth() as u64,
            oldest_age_ms: jc.oldest_queued_ms,
            submitted: js.submitted,
            rejected_quota: js.rejected_quota,
            rejected_depth: js.rejected_depth,
            retries: js.retries,
        };
        let alerts = self
            .alerts
            .snapshot()
            .into_iter()
            .map(|a| AlertStatus {
                rule: a.rule,
                describe: a.describe,
                active: a.active,
                since_ms: a.since_ms,
                value: a.value,
            })
            .collect();
        StatusReply {
            perf,
            jobs,
            alerts,
            uptime_ms: self.started.elapsed().as_millis() as u64,
            workers: self.workers as u64,
            requests: self.requests.load(Ordering::Relaxed),
            search: SearchCounters {
                requests: self.search_requests.load(Ordering::Relaxed),
                cold: self.cold_searches.load(Ordering::Relaxed),
                warm: self.warm_searches.load(Ordering::Relaxed),
                scheduler_evals_total: self.scheduler_evals_total.load(Ordering::Relaxed),
            },
            coalescer: CoalescerCounters {
                led: self.coalescer.led.load(Ordering::Relaxed),
                coalesced: self.coalescer.coalesced.load(Ordering::Relaxed),
                in_flight: self.coalescer.in_flight() as u64,
            },
            db: DbCounters {
                path: self.db.path().map(|p| p.display().to_string()),
                entries: db.entries as u64,
                loaded: db.loaded as u64,
                appended: db.appended,
                hits: db.hits,
                misses: db.misses,
            },
        }
    }
}

/// Scrape-time samples for `GET /metrics`: per-instance state that must
/// NOT live in the process-global registry (tests start several services
/// in one process, and their counters would collide). The process-global
/// counters (`wham_backend_rows_total`, …) render alongside these from
/// the registry itself.
impl Collect for ServiceState {
    fn collect(&self, out: &mut Vec<Sample>) {
        let n = |v: &AtomicU64| v.load(Ordering::Relaxed);
        let label = |k: &str, v: &str| vec![(k.to_string(), v.to_string())];
        out.push(Sample::Counter {
            name: "wham_http_requests_total".into(),
            help: "HTTP requests handled by this service instance.".into(),
            labels: vec![],
            value: n(&self.requests),
        });
        out.push(Sample::Counter {
            name: "wham_search_requests_total".into(),
            help: "POST /search requests that validated into a plan.".into(),
            labels: vec![],
            value: n(&self.search_requests),
        });
        for (kind, v) in
            [("cold", n(&self.cold_searches)), ("warm", n(&self.warm_searches))]
        {
            out.push(Sample::Counter {
                name: "wham_search_leader_computations_total".into(),
                help: "Search leader computations by outcome: cold ran the \
                       scheduler, warm answered entirely from the database."
                    .into(),
                labels: label("result", kind),
                value: v,
            });
        }
        out.push(Sample::Counter {
            name: "wham_service_scheduler_evals_total".into(),
            help: "Scheduler invocations across this instance's leader computations.".into(),
            labels: vec![],
            value: n(&self.scheduler_evals_total),
        });
        for (role, v) in [
            ("led", self.coalescer.led.load(Ordering::Relaxed)),
            ("coalesced", self.coalescer.coalesced.load(Ordering::Relaxed)),
        ] {
            out.push(Sample::Counter {
                name: "wham_coalescer_requests_total".into(),
                help: "Coalescable requests by role (leader vs follower).".into(),
                labels: label("role", role),
                value: v,
            });
        }
        out.push(Sample::Gauge {
            name: "wham_coalescer_in_flight".into(),
            help: "Coalesced computations currently executing.".into(),
            labels: vec![],
            value: self.coalescer.in_flight() as f64,
        });
        let jc = self.jobs.counts();
        for (state, v) in [
            ("queued", jc.queued),
            ("running", jc.running),
            ("done", jc.done),
            ("failed", jc.failed),
            ("cancelled", jc.cancelled),
        ] {
            out.push(Sample::Gauge {
                name: "wham_jobs_total".into(),
                help: "Jobs in the store by lifecycle state.".into(),
                labels: label("state", state),
                value: v as f64,
            });
        }
        out.push(Sample::Gauge {
            name: "wham_jobs_queue_depth".into(),
            help: "Jobs waiting in the dispatcher queue.".into(),
            labels: vec![],
            value: self.jobs.queue_depth() as f64,
        });
        out.push(Sample::Gauge {
            name: "wham_jobs_oldest_age_ms".into(),
            help: "Age of the oldest still-queued job (0 when the queue is empty).".into(),
            labels: vec![],
            value: jc.oldest_queued_ms as f64,
        });
        let js = self.jobs.stats();
        out.push(Sample::Counter {
            name: "wham_jobs_submitted_total".into(),
            help: "Job submissions admitted since boot.".into(),
            labels: vec![],
            value: js.submitted,
        });
        for (reason, v) in [("quota", js.rejected_quota), ("queue_full", js.rejected_depth)] {
            out.push(Sample::Counter {
                name: "wham_jobs_rejected_total".into(),
                help: "Job submissions rejected at the door, by reason.".into(),
                labels: label("reason", reason),
                value: v,
            });
        }
        out.push(Sample::Counter {
            name: "wham_jobs_retries_total".into(),
            help: "Transient-failure retries scheduled since boot.".into(),
            labels: vec![],
            value: js.retries,
        });
        let db = self.db.stats();
        let probes = db.hits + db.misses;
        out.push(Sample::Gauge {
            name: "wham_db_hit_rate".into(),
            help: "Design-database probe hit rate since start (0 before any probe).".into(),
            labels: vec![],
            value: if probes == 0 { 0.0 } else { db.hits as f64 / probes as f64 },
        });
        out.push(Sample::Gauge {
            name: "wham_db_entries".into(),
            help: "Design points currently in the database.".into(),
            labels: vec![],
            value: db.entries as f64,
        });
        for ring in &self.latency {
            if let Some(stat) = ring.stat() {
                out.push(Sample::Summary {
                    name: "wham_http_request_duration_ms".into(),
                    help: "Request wall-clock per endpoint over the latest window \
                           (includes error responses and coalesced followers)."
                        .into(),
                    labels: label("endpoint", &stat.endpoint),
                    quantiles: vec![(0.5, stat.p50_ms), (0.95, stat.p95_ms)],
                    count: stat.count,
                });
            }
        }
        // The same windows, bucketed: real `_bucket` series for alerting
        // math the two-quantile summary can't support. Window semantics
        // (latest CAP requests, not since-boot) are shared with the
        // summary above.
        for ring in &self.latency {
            let window: Vec<u32> = ring.samples.lock().unwrap().clone();
            if window.is_empty() {
                continue;
            }
            let (buckets, sum, count) = crate::telemetry::registry::log2_buckets(
                window.iter().map(|&v| u64::from(v)),
                1e-6,
            );
            out.push(Sample::Histogram {
                name: "wham_http_request_duration_seconds".into(),
                help: "Bucketed request wall-clock per endpoint over the latest window."
                    .into(),
                labels: label("endpoint", ring.name),
                buckets,
                sum,
                count,
            });
        }
        // Trace-buffer and flight-recorder occupancy (process-global;
        // the drop *counters* ride the registry, these are the gauges).
        let buffered = crate::telemetry::trace::event_count();
        out.push(Sample::Gauge {
            name: "wham_trace_buffer_events".into(),
            help: "Span events currently held by the in-memory trace buffer.".into(),
            labels: vec![],
            value: buffered as f64,
        });
        out.push(Sample::Gauge {
            name: "wham_trace_buffer_occupancy".into(),
            help: "Trace-buffer fill fraction (events / capacity).".into(),
            labels: vec![],
            value: buffered as f64 / crate::telemetry::trace::CAP as f64,
        });
        let (records, shed) = crate::telemetry::recorder::last_occupancy();
        out.push(Sample::Gauge {
            name: "wham_flight_recorder_last_records".into(),
            help: "Explain records kept by the most recently finished search's \
                   flight recorder."
                .into(),
            labels: vec![],
            value: records as f64,
        });
        out.push(Sample::Gauge {
            name: "wham_flight_recorder_last_dropped".into(),
            help: "Explain records shed by the most recently finished search's \
                   flight recorder."
                .into(),
            labels: vec![],
            value: shed as f64,
        });
        out.push(Sample::Counter {
            name: "wham_http_responses_5xx_total".into(),
            help: "Responses answered with a 5xx status by this instance.".into(),
            labels: vec![],
            value: n(&self.responses_5xx),
        });
        // Jobs WAL size on disk (0 for in-memory stores) — the
        // `jobs-wal-growth` alert rule differentiates this gauge.
        let wal_bytes = self
            .jobs
            .store()
            .path()
            .and_then(|p| std::fs::metadata(p).ok())
            .map_or(0, |m| m.len());
        out.push(Sample::Gauge {
            name: "wham_jobs_wal_bytes".into(),
            help: "Jobs write-ahead log size on disk (0 for in-memory stores).".into(),
            labels: vec![],
            value: wal_bytes as f64,
        });
        out.push(Sample::Gauge {
            name: "wham_profiler_attached".into(),
            help: "Whether a span profiler session is currently attached (0/1).".into(),
            labels: vec![],
            value: f64::from(u8::from(crate::telemetry::profile::is_attached())),
        });
        for a in self.alerts.snapshot() {
            out.push(Sample::Gauge {
                name: "wham_alert_active".into(),
                help: "Whether the named alert rule is currently firing (0/1).".into(),
                labels: label("rule", &a.rule),
                value: f64::from(u8::from(a.active)),
            });
        }
        crate::telemetry::process::ProcessMetrics.collect(out);
    }
}

/// The HTTP handler: one [`Session`] (cost backend + shared design
/// database) per worker thread — PJRT clients are not `Sync`, the same
/// policy as [`crate::coordinator`].
pub struct Api {
    pub state: Arc<ServiceState>,
}

impl Handler for Api {
    type Ctx = Session;

    fn make_ctx(&self) -> Self::Ctx {
        // `start()` validated the choice once; an explicit-PJRT failure
        // here can only race an artifact deletion, so fall back rather
        // than serve nothing.
        let backend = make_backend(self.state.backend_choice)
            .unwrap_or_else(|_| Box::new(NativeCost));
        // Per-request fan-out budget: split the machine across the
        // request workers, so a lone heavy `/global` on a low-worker
        // deployment still scales with cores without oversubscribing a
        // fully-parallel one.
        let jobs = (crate::util::default_jobs() / self.state.workers.max(1)).max(1);
        Session::with_backend(backend).with_db(Arc::clone(&self.state.db)).with_jobs(jobs)
    }

    fn handle(&self, session: &mut Self::Ctx, req: &Request) -> Response {
        let s = &self.state;
        s.requests.fetch_add(1, Ordering::Relaxed);
        // One correlation id per request, bound to this thread for the
        // whole handler: every log line emitted below (including by a
        // job submission running admission on this thread) carries it,
        // and the client gets it back in `X-Wham-Request-Id`.
        let corr = mint_corr();
        let _corr_scope = CorrScope::enter(&corr);
        let t0 = Instant::now();
        let mut follower = false;
        let resp = match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/models") => Response::json(session.models().to_json()),
            ("GET", "/status") => Response::json(s.status().to_json()),
            ("GET", "/metrics") => metrics_response(s),
            ("GET", "/metrics/history") => history_response(s, &req.query),
            ("GET", "/dashboard") => Response::html(dashboard_html(s)),
            ("GET", "/alerts/events") => alerts_sse_response(Arc::clone(&s.alerts)),
            ("GET", "/profile") => profile_response(&req.query),
            ("POST", "/search") => search_response(s, session, &req.body, &mut follower),
            ("POST", "/evaluate") => api_result(
                EvaluateRequest::from_json_str(&req.body)
                    .and_then(|r| session.evaluate(&r))
                    .map(|reply| reply.to_json()),
            ),
            ("POST", "/common") => common_response(s, session, &req.body, &mut follower),
            ("POST", "/global") => global_response(s, session, &req.body, &mut follower),
            ("POST", "/cluster") => cluster_response(s, session, &req.body, &mut follower),
            ("POST", "/workloads") => api_result(upload_workload(&req.body)),
            ("POST", "/jobs") => submit_job(s, &req.body),
            ("GET", "/jobs") => Response::json(
                JobListReply {
                    jobs: s.jobs.store().list().iter().map(|r| r.to_reply()).collect(),
                }
                .to_json(),
            ),
            ("GET", "/db/export") => Response::text(s.db.export_jsonl(), "application/x-ndjson"),
            ("POST", "/db/import") => {
                let st = s.db.import_jsonl(&req.body);
                Response::json(
                    DbImportReply {
                        added: st.added,
                        duplicate: st.duplicate,
                        malformed: st.malformed,
                        entries: s.db.stats().entries as u64,
                    }
                    .to_json(),
                )
            }
            (
                _,
                "/models" | "/status" | "/metrics" | "/metrics/history" | "/dashboard"
                | "/alerts/events" | "/profile" | "/search" | "/evaluate" | "/common"
                | "/global" | "/cluster" | "/workloads" | "/jobs" | "/db/export"
                | "/db/import",
            ) => Response::error(405, "wrong method for this endpoint"),
            _ if req.path.starts_with("/jobs/") => job_response(s, req),
            _ => Response::error(
                404,
                "unknown endpoint; see GET /models, POST /workloads, POST /search, POST /evaluate, POST /common, POST /global, POST /cluster, POST /jobs, GET /jobs, GET /db/export, POST /db/import, GET /status, GET /metrics, GET /metrics/history, GET /dashboard, GET /alerts/events, GET /profile",
            ),
        };
        if resp.status >= 500 {
            s.responses_5xx.fetch_add(1, Ordering::Relaxed);
        }
        // Latency-window recording policy (pinned by the tests below):
        // every request whose path names a known endpoint records its
        // wall, regardless of outcome — 4xx/5xx responses count because
        // the client waited for them, and coalesced followers count
        // because their wait is what that client experienced (the leader
        // and its followers each record once). Unknown paths are not
        // tracked: their cardinality is attacker-controlled. Per-job
        // paths normalize onto one "/jobs" ring (ids are unbounded), and
        // the two /db endpoints share a "/db" ring.
        let ring_name = if req.path == "/jobs" || req.path.starts_with("/jobs/") {
            "/jobs"
        } else if req.path == "/db/export" || req.path == "/db/import" {
            "/db"
        } else {
            req.path.as_str()
        };
        if let Some(ring) = s.latency.iter().find(|r| r.name == ring_name) {
            ring.note(t0.elapsed());
        }
        // The access log: one structured line per request, every path
        // (unknown ones included — a single line has no cardinality
        // problem). For streamed responses `bytes` counts the buffered
        // body only (0 for SSE), and the wall is handler time.
        log::info(
            "http",
            "request",
            &[
                ("method", &req.method),
                ("path", &req.path),
                ("status", &resp.status),
                ("bytes", &resp.body.len()),
                ("us", &(t0.elapsed().as_micros() as u64)),
                ("coalesced", &follower),
            ],
        );
        resp.with_header("X-Wham-Request-Id", corr)
    }
}

/// `GET /metrics` — the Prometheus text exposition: every registered
/// process-global counter plus this instance's scrape-time samples.
fn metrics_response(s: &ServiceState) -> Response {
    // Touch the process-global counters so a scrape before any search
    // still exposes every counter `/status.perf` reports (`get()`
    // lazily registers them).
    crate::cost::backend_rows_total();
    crate::sched::evals_total();
    crate::cluster::events_total();
    crate::telemetry::trace::events_recorded_total();
    crate::telemetry::trace::events_dropped_total();
    let collect: &dyn Collect = s;
    Response::prometheus(crate::telemetry::render_prometheus(&[collect]))
}

/// `GET /metrics/history?series=<glob>&window=<secs>` — typed JSON
/// samples from the tsdb: counter series as windowed per-second rates,
/// gauges verbatim. `series` defaults to `*`, `window` to the span the
/// fine tier covers.
fn history_response(s: &ServiceState, query: &str) -> Response {
    let opts = s.tsdb.options();
    let fine_span =
        (opts.fine_every.as_secs_f64() * opts.fine_cap as f64).ceil() as u64;
    let mut pattern = "*".to_string();
    let mut window = fine_span;
    for pair in query.split('&') {
        let Some((k, v)) = pair.split_once('=') else { continue };
        match k {
            "series" => pattern = v.to_string(),
            "window" => match v.parse::<u64>() {
                Ok(n) if n >= 1 => window = n,
                _ => return Response::error(400, "window must be a positive integer (seconds)"),
            },
            _ => {}
        }
    }
    Response::json(s.tsdb.history_json(&pattern, window, crate::telemetry::tsdb::epoch_ms()))
}

/// Inline SVG sparkline over `(t_ms, v)` points — the dashboard's only
/// graphic, so the page stays a single self-contained document.
fn spark_svg(points: &[(u64, f64)]) -> String {
    const W: f64 = 260.0;
    const H: f64 = 44.0;
    if points.len() < 2 {
        return format!(
            "<svg class=\"spark\" viewBox=\"0 0 {W} {H}\" width=\"{W}\" height=\"{H}\"><text x=\"4\" y=\"26\" class=\"dim\">collecting…</text></svg>"
        );
    }
    let (t0, t1) = (points[0].0 as f64, points[points.len() - 1].0 as f64);
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(_, v) in points {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span_t = (t1 - t0).max(1.0);
    let span_v = (hi - lo).max(1e-9);
    let pts: Vec<String> = points
        .iter()
        .map(|&(t, v)| {
            let x = (t as f64 - t0) / span_t * (W - 4.0) + 2.0;
            let y = H - 4.0 - (v - lo) / span_v * (H - 8.0);
            format!("{x:.1},{y:.1}")
        })
        .collect();
    format!(
        "<svg class=\"spark\" viewBox=\"0 0 {W} {H}\" width=\"{W}\" height=\"{H}\"><polyline fill=\"none\" stroke=\"#4c9aff\" stroke-width=\"1.5\" points=\"{}\"/></svg>",
        pts.join(" ")
    )
}

/// Escape text interpolated into the dashboard HTML.
fn html_esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

/// `GET /dashboard` — one self-contained HTML page (inline CSS + SVG,
/// zero external assets, meta-refresh every 5 s): throughput and queue
/// sparklines from the tsdb, per-endpoint latency quantiles, DB
/// hit-rate, process info, and the alert table.
fn dashboard_html(s: &ServiceState) -> String {
    let now_ms = crate::telemetry::tsdb::epoch_ms();
    let opts = s.tsdb.options();
    let window = (opts.fine_every.as_secs_f64() * opts.fine_cap as f64).ceil() as u64;
    let latest_of = |series: &str| s.tsdb.query(series, window, now_ms).into_iter().next();
    let fmt_v = |v: f64| {
        if v.abs() >= 100.0 {
            format!("{v:.0}")
        } else if v.abs() >= 1.0 {
            format!("{v:.1}")
        } else {
            format!("{v:.3}")
        }
    };
    let card = |title: &str, unit: &str, series: &str| {
        let (spark, last) = match latest_of(series) {
            Some(out) => {
                let last = out.points.last().map(|&(_, v)| v).unwrap_or(0.0);
                (spark_svg(&out.points), fmt_v(last))
            }
            None => (spark_svg(&[]), "—".to_string()),
        };
        format!(
            "<div class=\"card\"><div class=\"t\">{}</div><div class=\"v\">{last}<span class=\"u\">{unit}</span></div>{spark}</div>",
            html_esc(title)
        )
    };
    let cards = [
        card("scheduler evals", "/s", "wham_scheduler_evals_total"),
        card("event-sim events", "/s", "wham_cluster_sim_events_total"),
        card("http requests", "/s", "wham_http_requests_total"),
        card("job queue depth", "", "wham_jobs_queue_depth"),
        card("job retries", "/s", "wham_jobs_retries_total"),
        card("db hit-rate", "", "wham_db_hit_rate"),
    ]
    .join("\n");
    let mut latency_rows = String::new();
    for stat in s.latency.iter().filter_map(LatencyRing::stat) {
        latency_rows.push_str(&format!(
            "<tr><td>{}</td><td class=\"n\">{}</td><td class=\"n\">{:.2}</td><td class=\"n\">{:.2}</td></tr>",
            html_esc(&stat.endpoint),
            stat.count,
            stat.p50_ms,
            stat.p95_ms
        ));
    }
    let mut alert_rows = String::new();
    let mut firing = 0usize;
    for a in s.alerts.snapshot() {
        if a.active {
            firing += 1;
        }
        let (cls, word) = if a.active { ("firing", "FIRING") } else { ("ok", "ok") };
        alert_rows.push_str(&format!(
            "<tr class=\"{cls}\"><td>{}</td><td>{word}</td><td class=\"n\">{}</td><td>{}</td></tr>",
            html_esc(&a.rule),
            fmt_v(a.value),
            html_esc(&a.describe)
        ));
    }
    let (version, sha) = crate::telemetry::process::build_info();
    let status = s.status();
    let head_class = if firing > 0 { "firing" } else { "ok" };
    let head_word =
        if firing > 0 { format!("{firing} alert(s) firing") } else { "all clear".to_string() };
    format!(
        r#"<!DOCTYPE html>
<html lang="en"><head><meta charset="utf-8">
<meta http-equiv="refresh" content="5">
<title>wham dashboard</title>
<style>
body {{ font: 13px/1.45 -apple-system, system-ui, sans-serif; background:#10141a; color:#d7dee8; margin:1.2em; }}
h1 {{ font-size:1.1em; margin:0 0 .2em; }}
h2 {{ font-size:.95em; margin:1.4em 0 .4em; color:#9fb0c3; }}
.meta {{ color:#7d8b9d; }}
.grid {{ display:flex; flex-wrap:wrap; gap:.8em; }}
.card {{ background:#171d26; border:1px solid #232c38; border-radius:6px; padding:.6em .8em; }}
.card .t {{ color:#9fb0c3; }}
.card .v {{ font-size:1.5em; }}
.card .u {{ font-size:.6em; color:#7d8b9d; margin-left:.25em; }}
.spark {{ display:block; margin-top:.3em; }}
.dim {{ fill:#55626f; font-size:11px; }}
table {{ border-collapse:collapse; }}
td, th {{ padding:.15em .7em .15em 0; text-align:left; }}
td.n {{ text-align:right; font-variant-numeric:tabular-nums; }}
tr.firing td {{ color:#ff6b6b; }}
tr.ok td {{ color:#8fd19e; }}
.badge.firing {{ color:#ff6b6b; }}
.badge.ok {{ color:#8fd19e; }}
</style></head><body>
<h1>wham serve <span class="badge {head_class}">{head_word}</span></h1>
<div class="meta">v{version} ({sha}) · uptime {uptime_s}s · {workers} worker(s) · {requests} request(s) · rss {rss_mib} MiB · {threads} thread(s) · window {window}s</div>
<h2>throughput &amp; queues</h2>
<div class="grid">
{cards}
</div>
<h2>alerts</h2>
<table><tr><th>rule</th><th>state</th><th>value</th><th>describe</th></tr>{alert_rows}</table>
<h2>endpoint latency (window p50/p95 ms)</h2>
<table><tr><th>endpoint</th><th>count</th><th>p50</th><th>p95</th></tr>{latency_rows}</table>
<div class="meta">history: <code>GET /metrics/history?series=wham_*&amp;window={window}</code> · stream: <code>GET /alerts/events</code> · cli: <code>wham top</code></div>
</body></html>
"#,
        uptime_s = status.uptime_ms / 1000,
        workers = status.workers,
        requests = status.requests,
        rss_mib = crate::telemetry::process::rss_bytes() / (1024 * 1024),
        threads = crate::telemetry::process::thread_count(),
    )
}

/// `GET /alerts/events` — SSE stream of alert transitions (`fire` /
/// `resolve` frames) over the same chunked plumbing as the jobs tier.
/// Opens with a `snapshot` frame of the current rule states; the stream
/// has no terminal frame — alerts outlive any one episode — so idle
/// periods carry comment keepalives until the client disconnects.
fn alerts_sse_response(alerts: Arc<AlertEngine>) -> Response {
    Response::stream(
        "text/event-stream",
        Box::new(move |w| {
            let snapshot: Vec<String> = alerts
                .snapshot()
                .into_iter()
                .map(|a| {
                    crate::util::json::Obj::new()
                        .str("rule", &a.rule)
                        .bool("active", a.active)
                        .u64("since_ms", a.since_ms)
                        .f64("value", a.value)
                        .finish()
                })
                .collect();
            w.write_all(
                sse_frame(Some("snapshot"), &crate::util::json::arr(snapshot)).as_bytes(),
            )?;
            w.flush()?;
            let mut from = alerts.frame_head();
            loop {
                let (frames, next) = alerts.wait(from, Duration::from_secs(10));
                from = next;
                for f in &frames {
                    w.write_all(f.as_bytes())?;
                }
                if frames.is_empty() {
                    w.write_all(b": keepalive\n\n")?;
                }
                w.flush()?;
            }
        }),
    )
}

/// `GET /profile?seconds=N&hz=M` — attach the span sampler for the
/// window and answer with folded-stack text (`path;leaf N` lines) for
/// `flamegraph.pl` / speedscope. Blocks one HTTP worker for the window
/// (bounded at 30 s); a concurrent profile answers 409.
fn profile_response(query: &str) -> Response {
    let mut seconds = 2u64;
    let mut hz = 99u32;
    for pair in query.split('&') {
        let Some((k, v)) = pair.split_once('=') else { continue };
        match k {
            "seconds" => match v.parse::<u64>() {
                Ok(n) if (1..=30).contains(&n) => seconds = n,
                _ => return Response::error(400, "seconds must be an integer in 1..=30"),
            },
            "hz" => match v.parse::<u32>() {
                Ok(n) if n >= 1 => hz = n,
                _ => return Response::error(400, "hz must be a positive integer"),
            },
            _ => {}
        }
    }
    match crate::telemetry::profile::profile_for(Duration::from_secs(seconds), hz) {
        Ok(p) => Response::text(p.collapsed(), "text/plain; charset=utf-8"),
        Err(e) => Response::error(409, e),
    }
}

/// Map a typed API outcome onto an HTTP response.
fn api_result(r: Result<String, ApiError>) -> Response {
    match r {
        Ok(body) => Response::json(body),
        Err(e) => Response::error(e.http_status(), &e.message),
    }
}

/// Unwrap a coalesced (string-typed) leader outcome.
fn into_response(outcome: &Result<String, String>) -> Response {
    match outcome {
        Ok(body) => Response::json(body.clone()),
        Err(e) => Response::error(500, e),
    }
}

/// Validate and register an uploaded workload spec. Spec diagnostics
/// (with layer paths) surface as 400s; the reply carries the training
/// fingerprint the design database will key the workload's points by.
fn upload_workload(body: &str) -> Result<String, ApiError> {
    let report = crate::workload::add_spec_text(body, crate::workload::Source::Uploaded)
        .map_err(|e| ApiError::invalid(e.to_string()))?;
    Ok(WorkloadReply {
        name: report.name,
        fingerprint: report.fingerprint,
        batch: report.batch,
        forward_ops: report.forward_ops as u64,
        training_ops: report.training_ops as u64,
        source: crate::workload::Source::Uploaded.label().to_string(),
    }
    .to_json())
}

fn search_response(
    s: &ServiceState,
    session: &mut Session,
    body: &str,
    follower: &mut bool,
) -> Response {
    let plan = match SearchRequest::from_json_str(body).and_then(|r| r.validate()) {
        Ok(p) => p,
        Err(e) => return api_result(Err(e)),
    };
    s.search_requests.fetch_add(1, Ordering::Relaxed);
    let key = plan.coalescing_key(session.backend_name());
    let (outcome, led) = s.coalescer.run(key, || {
        let reply = session.run_search(&plan, &mut NullSink).map_err(|e| e.message)?;
        if reply.scheduler_evals > 0 {
            s.cold_searches.fetch_add(1, Ordering::Relaxed);
        } else {
            s.warm_searches.fetch_add(1, Ordering::Relaxed);
        }
        s.scheduler_evals_total.fetch_add(reply.scheduler_evals, Ordering::Relaxed);
        Ok(reply.to_json())
    });
    *follower = !led;
    into_response(&outcome)
}

fn common_response(
    s: &ServiceState,
    session: &mut Session,
    body: &str,
    follower: &mut bool,
) -> Response {
    let plan = match CommonRequest::from_json_str(body).and_then(|r| r.validate()) {
        Ok(p) => p,
        Err(e) => return api_result(Err(e)),
    };
    let key = plan.coalescing_key(session.backend_name());
    let (outcome, led) = s.coalescer.run(key, || {
        session.run_common(&plan).map(|r| r.to_json()).map_err(|e| e.message)
    });
    *follower = !led;
    into_response(&outcome)
}

fn global_response(
    s: &ServiceState,
    session: &mut Session,
    body: &str,
    follower: &mut bool,
) -> Response {
    let plan = match GlobalRequest::from_json_str(body).and_then(|r| r.validate()) {
        Ok(p) => p,
        Err(e) => return api_result(Err(e)),
    };
    let key = plan.coalescing_key(session.backend_name());
    let (outcome, led) = s.coalescer.run(key, || {
        session.run_global(&plan, &mut NullSink).map(|r| r.to_json()).map_err(|e| e.message)
    });
    *follower = !led;
    into_response(&outcome)
}

fn cluster_response(
    s: &ServiceState,
    session: &mut Session,
    body: &str,
    follower: &mut bool,
) -> Response {
    let plan = match ClusterRequest::from_json_str(body).and_then(|r| r.validate()) {
        Ok(p) => p,
        Err(e) => return api_result(Err(e)),
    };
    let key = plan.coalescing_key(session.backend_name());
    let (outcome, led) = s.coalescer.run(key, || {
        session.run_cluster(&plan, &mut NullSink).map(|r| r.to_json()).map_err(|e| e.message)
    });
    *follower = !led;
    into_response(&outcome)
}

/// `POST /jobs` — validate at the door (400), admit through quota and
/// queue-depth gates (429/503 with `Retry-After`), answer 202 with the
/// queued job's record.
fn submit_job(s: &ServiceState, body: &str) -> Response {
    let plan = match JobRequest::from_json_str(body).and_then(|r| r.validate()) {
        Ok(p) => p,
        Err(e) => return api_result(Err(e)),
    };
    match s.jobs.submit(&plan) {
        Ok(rec) => Response::accepted(rec.to_reply().to_json()),
        Err(e) => {
            let (status, retry) = e.http();
            match retry {
                Some(secs) => Response::error_retry_after(status, &e.message(), secs),
                None => Response::error(status, &e.message()),
            }
        }
    }
}

/// Routes under `/jobs/:id` — poll, raw reply, SSE events, cancel.
fn job_response(s: &ServiceState, req: &Request) -> Response {
    let rest = &req.path["/jobs/".len()..];
    let (id, sub) = match rest.split_once('/') {
        Some((id, sub)) => (id, Some(sub)),
        None => (rest, None),
    };
    let Some(rec) = s.jobs.store().get(id) else {
        return Response::error(404, "no such job");
    };
    match (req.method.as_str(), sub) {
        ("GET", None) => Response::json(rec.to_reply().to_json()),
        ("DELETE", None) => match s.jobs.cancel(id) {
            Some(rec) => Response::json(rec.to_reply().to_json()),
            None => Response::error(404, "no such job"),
        },
        ("GET", Some("reply")) => match rec.reply {
            // The raw stored bytes — byte-identical to what the
            // synchronous endpoint sent for the same plan.
            Some(r) => Response::json(r),
            None => Response::error(404, "job has no reply yet (poll GET /jobs/:id for state)"),
        },
        ("GET", Some("events")) => sse_response(Arc::clone(&s.jobs), id.to_string()),
        (_, None | Some("reply") | Some("events")) => {
            Response::error(405, "wrong method for this endpoint")
        }
        _ => Response::error(404, "unknown job sub-resource (events, reply)"),
    }
}

/// `GET /jobs/:id/events` — Server-Sent Events over a chunked response.
/// Live progress frames are relayed from the dispatcher's per-job ring;
/// once the job is terminal the stream ends with an authoritative
/// `state` frame plus a `done` frame from the store. Late watchers of
/// already-terminal jobs get just those two frames.
fn sse_response(jobs: Arc<JobManager>, id: String) -> Response {
    Response::stream(
        "text/event-stream",
        Box::new(move |w| {
            let mut from = 0usize;
            if let Some(live) = jobs.watch(&id) {
                loop {
                    let (frames, next, terminal) = live.wait(from, Duration::from_secs(10));
                    from = next;
                    for f in &frames {
                        w.write_all(f.as_bytes())?;
                    }
                    if terminal {
                        break;
                    }
                    if frames.is_empty() {
                        // SSE comment keepalive: detects dead clients and
                        // defeats idle-connection middleboxes.
                        w.write_all(b": keepalive\n\n")?;
                    }
                    w.flush()?;
                }
            }
            if let Some(rec) = jobs.store().get(&id) {
                let reply = rec.to_reply();
                let brief = reply.to_json_brief();
                w.write_all(sse_frame(Some("state"), &brief).as_bytes())?;
                w.write_all(sse_frame(Some("done"), &brief).as_bytes())?;
            }
            Ok(())
        }),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::store::JobStore;
    use crate::jobs::JobsOptions;

    fn api_with(opts: JobsOptions) -> (Api, Session) {
        let db = Arc::new(DesignDb::in_memory());
        let jobs = JobManager::start(Arc::new(JobStore::in_memory()), opts, {
            let db = Arc::clone(&db);
            move || {
                Session::with_backend(Box::new(NativeCost)).with_db(Arc::clone(&db)).with_jobs(1)
            }
        });
        let state = Arc::new(ServiceState::new(
            db,
            BackendChoice::Native,
            1,
            jobs,
            TsdbOptions::default(),
        ));
        let api = Api { state };
        let session = api.make_ctx();
        (api, session)
    }

    fn api() -> (Api, Session) {
        api_with(JobsOptions { workers: 1, ..JobsOptions::default() })
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.to_string(),
            path: path.to_string(),
            query: String::new(),
            body: body.to_string(),
        }
    }

    fn ring_count(state: &ServiceState, path: &str) -> u64 {
        let ring = state.latency.iter().find(|r| r.name == path).expect("known endpoint");
        ring.stat().map_or(0, |s| s.count)
    }

    /// Pins the latency-recording policy: error responses (400 and 405)
    /// record under the endpoint the client hit, unknown paths are not
    /// tracked at all, and successes record too. Coalesced followers
    /// share this path structurally — `handle` notes the ring after
    /// `Coalescer::run` returns for leaders and followers alike.
    #[test]
    fn latency_rings_record_errors_and_skip_unknown_paths() {
        let (api, mut s) = api();
        let r = api.handle(&mut s, &req("POST", "/search", "{"));
        assert_eq!(r.status, 400, "malformed body: {}", r.body);
        assert_eq!(ring_count(&api.state, "/search"), 1, "4xx responses must record");

        let r = api.handle(&mut s, &req("DELETE", "/search", ""));
        assert_eq!(r.status, 405);
        assert_eq!(ring_count(&api.state, "/search"), 2, "405 responses must record");

        let r = api.handle(&mut s, &req("GET", "/nope", ""));
        assert_eq!(r.status, 404);
        assert!(
            api.state.latency.iter().all(|ring| ring.name != "/nope"),
            "unknown paths must not grow the ring set"
        );

        let r = api.handle(&mut s, &req("GET", "/status", ""));
        assert_eq!(r.status, 200);
        assert_eq!(ring_count(&api.state, "/status"), 1);
    }

    #[test]
    fn metrics_exposes_status_perf_counters_as_prometheus_text() {
        let (api, mut s) = api();
        let r = api.handle(&mut s, &req("POST", "/search", "{\"model\":\"bert-base\"}"));
        assert_eq!(r.status, 200, "search failed: {}", r.body);

        let m = api.handle(&mut s, &req("GET", "/metrics", ""));
        assert_eq!(m.status, 200);
        assert!(m.content_type.starts_with("text/plain"), "{}", m.content_type);
        for name in [
            "wham_backend_rows_total",
            "wham_scheduler_evals_total",
            "wham_db_hit_rate",
            "wham_http_requests_total",
            "wham_search_leader_computations_total{result=\"cold\"}",
            "wham_http_request_duration_ms{endpoint=\"/search\",quantile=\"0.5\"}",
        ] {
            assert!(
                m.body.lines().any(|l| l.starts_with(name)),
                "missing {name} in exposition:\n{}",
                m.body
            );
        }
        // Scrapes record into their own ring (the body is rendered
        // before the note, so a scrape never sees itself).
        assert_eq!(ring_count(&api.state, "/metrics"), 1);
    }

    #[test]
    fn jobs_endpoints_admit_reject_and_report() {
        // A one-token bucket that refills glacially: the second submit
        // must be a 429 with Retry-After.
        let (api, mut s) = api_with(JobsOptions {
            workers: 1,
            quota_rate: 0.001,
            quota_burst: 1.0,
            ..JobsOptions::default()
        });
        let body = r#"{"client":"ci","request":{"model":"alexnet"}}"#;
        let r = api.handle(&mut s, &req("POST", "/jobs", body));
        assert_eq!(r.status, 202, "{}", r.body);
        let v = crate::util::json::parse(&r.body).unwrap();
        let id = v.get("id").unwrap().as_str().unwrap().to_string();
        assert_eq!(v.get("state").unwrap().as_str(), Some("queued"));

        let r = api.handle(&mut s, &req("POST", "/jobs", body));
        assert_eq!(r.status, 429, "{}", r.body);
        assert!(
            r.headers.iter().any(|(k, _)| *k == "Retry-After"),
            "429 must carry Retry-After"
        );

        // Inner-request validation runs at admission: a bad job is an
        // HTTP error at POST time, never a failed job found by polling.
        let r = api.handle(&mut s, &req("POST", "/jobs", r#"{"request":{"model":"nope"}}"#));
        assert_eq!(r.status, 404, "unknown model surfaces the inner error: {}", r.body);

        let r = api.handle(&mut s, &req("GET", &format!("/jobs/{id}"), ""));
        assert_eq!(r.status, 200);
        let r = api.handle(&mut s, &req("GET", "/jobs", ""));
        assert!(r.body.contains(&id), "{}", r.body);
        let r = api.handle(&mut s, &req("GET", "/jobs/j-nope-0000", ""));
        assert_eq!(r.status, 404);
        let r = api.handle(&mut s, &req("PUT", &format!("/jobs/{id}"), ""));
        assert_eq!(r.status, 405);

        // All of the above recorded under the one "/jobs" ring.
        assert_eq!(ring_count(&api.state, "/jobs"), 7);

        // /status carries the same admission counters the manager holds.
        let status = api.state.status();
        assert_eq!(status.jobs.submitted, 1);
        assert_eq!(status.jobs.rejected_quota, 1);

        // Wait for the job so its worker thread is not killed mid-search
        // when the test process tears down shared state.
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let rec = api.state.jobs.store().get(&id).unwrap();
            if rec.state.is_terminal() {
                assert_eq!(rec.state, crate::api::job::JobState::Done, "{:?}", rec.error);
                break;
            }
            assert!(Instant::now() < deadline, "job stuck");
            std::thread::sleep(Duration::from_millis(20));
        }
        // The raw reply endpoint serves the stored bytes.
        let r = api.handle(&mut s, &req("GET", &format!("/jobs/{id}/reply"), ""));
        assert_eq!(r.status, 200);
        assert!(r.body.contains("\"best\""), "{}", r.body);
    }

    /// The handler-level halves of the tentpole surface: `/dashboard`
    /// renders self-contained HTML, `/metrics/history` serves what the
    /// tsdb holds, `/status` + `/metrics` expose the alert rules, and
    /// 5xx responses feed the alert counter.
    #[test]
    fn dashboard_history_and_alert_surfaces_respond() {
        let (api, mut s) = api();
        // Simulate two scraper ticks so counter series have a rate.
        let now = crate::telemetry::tsdb::epoch_ms();
        let collect: &dyn Collect = &*api.state;
        api.state.tsdb.scrape(now.saturating_sub(2000), &[collect]);
        crate::sched::evals_total(); // touch so the registry has the series
        api.state.tsdb.scrape(now, &[collect]);
        api.state.alerts.evaluate(&api.state.tsdb, now);

        let r = api.handle(&mut s, &req("GET", "/dashboard", ""));
        assert_eq!(r.status, 200);
        assert!(r.content_type.starts_with("text/html"), "{}", r.content_type);
        assert!(r.body.contains("<svg"), "dashboard must inline sparklines");
        assert!(r.body.contains("job-queue-pressure"), "alert table missing:\n{}", r.body);
        for external in ["http://", "https://", "<script src", "<link "] {
            assert!(!r.body.contains(external), "external ref {external:?} in dashboard");
        }
        assert_eq!(ring_count(&api.state, "/dashboard"), 1);

        let r = api.handle(&mut s, &req("GET", "/metrics/history", ""));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = crate::util::json::parse(&r.body).unwrap();
        assert!(
            !v.get("series").unwrap().as_arr().unwrap().is_empty(),
            "history must be non-empty after two scrapes: {}",
            r.body
        );
        assert_eq!(ring_count(&api.state, "/metrics/history"), 1);

        // Bad window is a 400; wrong method is a 405, not a 404.
        let mut bad = req("GET", "/metrics/history", "");
        bad.query = "window=0".into();
        assert_eq!(api.handle(&mut s, &bad).status, 400);
        assert_eq!(api.handle(&mut s, &req("POST", "/dashboard", "")).status, 405);
        assert_eq!(api.handle(&mut s, &req("POST", "/alerts/events", "")).status, 405);

        // /status carries every rule; /metrics carries the 0/1 gauges
        // and the profiler/process satellites.
        let status = api.state.status();
        assert_eq!(status.alerts.len(), 4, "{:?}", status.alerts);
        assert!(status.alerts.iter().all(|a| !a.active), "{:?}", status.alerts);
        let m = api.handle(&mut s, &req("GET", "/metrics", ""));
        for name in [
            "wham_alert_active{rule=\"job-queue-pressure\"}",
            "wham_alert_active{rule=\"http-5xx\"}",
            "wham_profiler_attached",
            "wham_build_info{",
            "wham_process_resident_memory_bytes",
            "wham_http_responses_5xx_total",
            "wham_jobs_wal_bytes",
        ] {
            assert!(
                m.body.lines().any(|l| l.starts_with(name)),
                "missing {name} in exposition:\n{}",
                m.body
            );
        }
    }

    #[test]
    fn db_export_import_round_trips_through_the_handlers() {
        let (api, mut s) = api();
        // Populate the DB via a synchronous search.
        let r = api.handle(&mut s, &req("POST", "/search", "{\"model\":\"alexnet\"}"));
        assert_eq!(r.status, 200, "{}", r.body);
        let r = api.handle(&mut s, &req("GET", "/db/export", ""));
        assert_eq!(r.status, 200);
        assert!(!r.body.is_empty(), "export of a mined DB must not be empty");
        let export = r.body;

        // Import into a fresh service: everything is new.
        let (api2, mut s2) = api();
        let r = api2.handle(&mut s2, &req("POST", "/db/import", &export));
        assert_eq!(r.status, 200, "{}", r.body);
        let v = crate::util::json::parse(&r.body).unwrap();
        let added = v.get("added").unwrap().as_u64().unwrap();
        assert!(added > 0);
        assert_eq!(v.get("malformed").unwrap().as_u64(), Some(0));
        // Re-import: all duplicates now.
        let r = api2.handle(&mut s2, &req("POST", "/db/import", &export));
        let v = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(v.get("added").unwrap().as_u64(), Some(0));
        assert_eq!(v.get("duplicate").unwrap().as_u64(), Some(added));
        // Both /db endpoints share one ring.
        assert_eq!(ring_count(&api2.state, "/db"), 2);
    }
}
