//! In-flight request coalescing.
//!
//! A mining service sees bursts of identical requests (many tenants
//! asking for the same `<workload, options>` search). Running the search
//! once and fanning the response out is the classic single-flight
//! pattern: the first requester becomes the *leader* and computes; every
//! identical request that arrives while the computation is in flight
//! becomes a *follower* and blocks on a condvar for the leader's result.
//! Requests arriving after completion are served by the design database
//! instead — coalescing only ever holds work that is literally running.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Outcome shared between the leader and its followers.
type Shared = Arc<Slot>;

struct Slot {
    done: Mutex<Option<Arc<Result<String, String>>>>,
    cv: Condvar,
}

impl Slot {
    fn new() -> Self {
        Self { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn publish(&self, out: Arc<Result<String, String>>) {
        *self.done.lock().unwrap() = Some(out);
        self.cv.notify_all();
    }

    fn wait(&self) -> Arc<Result<String, String>> {
        let mut g = self.done.lock().unwrap();
        while g.is_none() {
            g = self.cv.wait(g).unwrap();
        }
        g.as_ref().unwrap().clone()
    }
}

/// Coalesces identical in-flight computations by key.
#[derive(Default)]
pub struct Coalescer {
    in_flight: Mutex<HashMap<u64, Shared>>,
    /// Requests served by joining an in-flight leader.
    pub coalesced: AtomicU64,
    /// Leader computations actually run.
    pub led: AtomicU64,
}

impl Coalescer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of computations currently in flight.
    pub fn in_flight(&self) -> usize {
        self.in_flight.lock().unwrap().len()
    }

    /// Run `compute` once per concurrent batch of callers sharing `key`.
    /// Returns the (shared) outcome and whether this caller led. A panic
    /// in the leader's `compute` is caught and surfaced to every waiter
    /// as an `Err` — one poisoned request must not wedge its followers.
    pub fn run<F>(&self, key: u64, compute: F) -> (Arc<Result<String, String>>, bool)
    where
        F: FnOnce() -> Result<String, String>,
    {
        let (slot, leader) = {
            let mut m = self.in_flight.lock().unwrap();
            match m.entry(key) {
                std::collections::hash_map::Entry::Occupied(e) => (e.get().clone(), false),
                std::collections::hash_map::Entry::Vacant(v) => {
                    let s = Arc::new(Slot::new());
                    v.insert(s.clone());
                    (s, true)
                }
            }
        };
        if !leader {
            self.coalesced.fetch_add(1, Ordering::Relaxed);
            return (slot.wait(), false);
        }
        self.led.fetch_add(1, Ordering::Relaxed);
        let out = Arc::new(match catch_unwind(AssertUnwindSafe(compute)) {
            Ok(r) => r,
            Err(p) => {
                Err(format!("search worker panicked: {}", crate::util::panic_text(&p)))
            }
        });
        // Unregister *before* publishing so a request racing with the
        // tail of the computation either joins this result or starts a
        // fresh computation — never waits on a slot nobody will fill.
        self.in_flight.lock().unwrap().remove(&key);
        slot.publish(out.clone());
        (out, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn identical_keys_coalesce_to_one_computation() {
        let c = Arc::new(Coalescer::new());
        let runs = Arc::new(AtomicUsize::new(0));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));

        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                let runs = Arc::clone(&runs);
                let gate = Arc::clone(&gate);
                std::thread::spawn(move || {
                    let (out, _) = c.run(42, || {
                        runs.fetch_add(1, Ordering::SeqCst);
                        // Block until every thread has had a chance to join.
                        let (lock, cv) = &*gate;
                        let mut open = lock.lock().unwrap();
                        while !*open {
                            open = cv.wait(open).unwrap();
                        }
                        Ok("result".to_string())
                    });
                    out
                })
            })
            .collect();

        // Open the gate only once all 7 followers joined the leader, so
        // no thread can arrive late and become a second leader.
        while c.coalesced.load(Ordering::SeqCst) < 7 {
            std::thread::yield_now();
        }
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        for t in threads {
            let out = t.join().unwrap();
            assert_eq!(out.as_ref().as_ref().unwrap(), "result");
        }
        assert_eq!(runs.load(Ordering::SeqCst), 1, "leader must run exactly once");
        assert_eq!(c.led.load(Ordering::Relaxed), 1);
        assert_eq!(c.coalesced.load(Ordering::Relaxed), 7);
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn different_keys_do_not_coalesce() {
        let c = Coalescer::new();
        let (a, led_a) = c.run(1, || Ok("a".into()));
        let (b, led_b) = c.run(2, || Ok("b".into()));
        assert!(led_a && led_b);
        assert_eq!(a.as_ref().as_ref().unwrap(), "a");
        assert_eq!(b.as_ref().as_ref().unwrap(), "b");
    }

    #[test]
    fn leader_panic_becomes_error_for_everyone() {
        let c = Coalescer::new();
        let (out, leader) = c.run(7, || panic!("boom"));
        assert!(leader);
        assert!(out.as_ref().as_ref().unwrap_err().contains("boom"));
        // The key is free again afterwards.
        let (out, _) = c.run(7, || Ok("recovered".into()));
        assert_eq!(out.as_ref().as_ref().unwrap(), "recovered");
    }
}
