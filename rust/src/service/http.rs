//! Dependency-free HTTP/1.1 plumbing on `std::net` (the offline cache
//! has no tokio/hyper; blocking OS threads follow the same substitution
//! the [`crate::coordinator`] makes for the search fan-out).
//!
//! One bounded pool of worker threads serves all connections; each
//! worker owns per-thread state built by [`Handler::make_ctx`] — the
//! mining service puts its (non-`Sync`) cost backend there. Connections
//! are `Connection: close`: one request, one response, which keeps the
//! parser ~100 lines and is plenty for a mining-service request profile
//! where the work dwarfs connection setup.
//!
//! Two hardening properties hold per connection: a slowloris client
//! (trickling bytes, or oversized head/body) costs one `408`/`413`
//! response instead of pinning a worker, and a [`Response`] may carry a
//! streaming body (`Transfer-Encoding: chunked`, flushed per write) —
//! the transport under `GET /jobs/:id/events` SSE.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Cap on request bodies (1 MiB) — mining requests are tiny JSON, and
/// design-DB imports of a few thousand entries still fit comfortably.
const MAX_BODY: usize = 1 << 20;
/// Cap on the request line + headers (64 KiB).
const MAX_HEAD: usize = 64 << 10;
/// Socket read/write timeout. Bounds how long an idle or trickling
/// client can pin a pool worker; compute time (searches) is unaffected
/// because it happens between the read and the write.
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// One parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without the query string (`/search`).
    pub path: String,
    /// Raw query string after `?`, possibly empty.
    pub query: String,
    pub body: String,
}

/// A streaming response body: called with a writer whose every `write`
/// becomes one flushed HTTP chunk. Returning `Err` (client gone) simply
/// ends the response.
pub type StreamBody = Box<dyn FnOnce(&mut dyn Write) -> std::io::Result<()> + Send>;

/// An HTTP response to be serialized.
pub struct Response {
    pub status: u16,
    pub body: String,
    /// `Content-Type` header value. Everything in the API is JSON except
    /// `GET /metrics` (Prometheus text) and `GET /jobs/:id/events` (SSE).
    pub content_type: &'static str,
    /// Extra headers, e.g. `Retry-After` on 429/503.
    pub headers: Vec<(&'static str, String)>,
    /// When set, the response is sent `Transfer-Encoding: chunked` and
    /// this closure produces the body; `body` is ignored.
    pub stream: Option<StreamBody>,
}

impl Response {
    fn base(status: u16, body: String, content_type: &'static str) -> Self {
        Self { status, body, content_type, headers: Vec::new(), stream: None }
    }

    /// 200 with a JSON body.
    pub fn json(body: impl Into<String>) -> Self {
        Self::base(200, body.into(), "application/json")
    }

    /// 202 Accepted with a JSON body (`POST /jobs`).
    pub fn accepted(body: impl Into<String>) -> Self {
        Self::base(202, body.into(), "application/json")
    }

    /// 200 with a Prometheus text-exposition body (`GET /metrics`).
    pub fn prometheus(body: impl Into<String>) -> Self {
        Self::base(200, body.into(), "text/plain; version=0.0.4; charset=utf-8")
    }

    /// 200 with an arbitrary content type (e.g. a JSONL export).
    pub fn text(body: impl Into<String>, content_type: &'static str) -> Self {
        Self::base(200, body.into(), content_type)
    }

    /// 200 with an HTML body (`GET /dashboard`).
    pub fn html(body: impl Into<String>) -> Self {
        Self::base(200, body.into(), "text/html; charset=utf-8")
    }

    /// An error with a `{"error": ...}` JSON body.
    pub fn error(status: u16, msg: &str) -> Self {
        Self::base(
            status,
            format!("{{\"error\":{}}}", crate::util::json::esc(msg)),
            "application/json",
        )
    }

    /// [`Response::error`] plus a `Retry-After: secs` header (429/503
    /// admission rejections).
    pub fn error_retry_after(status: u16, msg: &str, secs: u64) -> Self {
        Self::error(status, msg).with_header("Retry-After", secs.to_string())
    }

    /// A chunked streaming response (`text/event-stream` for SSE).
    pub fn stream(content_type: &'static str, f: StreamBody) -> Self {
        Self { stream: Some(f), ..Self::base(200, String::new(), content_type) }
    }

    /// Attach an extra header.
    pub fn with_header(mut self, name: &'static str, value: String) -> Self {
        self.headers.push((name, value));
        self
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            408 => "Request Timeout",
            409 => "Conflict",
            413 => "Payload Too Large",
            429 => "Too Many Requests",
            503 => "Service Unavailable",
            _ => "Internal Server Error",
        }
    }
}

/// Why reading a request failed — drives the status code so a slow or
/// oversized client gets an honest 408/413 instead of a generic 400.
#[derive(Debug)]
enum ReadError {
    /// Socket timed out mid-read (slowloris or dead peer).
    Timeout,
    /// Head or declared body beyond the caps.
    TooLarge(&'static str),
    /// Anything else unparseable.
    Malformed(String),
}

fn classify_io(e: std::io::Error) -> ReadError {
    match e.kind() {
        std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock => ReadError::Timeout,
        _ => ReadError::Malformed(e.to_string()),
    }
}

/// Per-worker request handler. `make_ctx` runs *on* the worker thread, so
/// the context does not need to be `Send` — only the handler itself is
/// shared.
pub trait Handler: Send + Sync + 'static {
    type Ctx;
    fn make_ctx(&self) -> Self::Ctx;
    fn handle(&self, ctx: &mut Self::Ctx, req: &Request) -> Response;
}

/// Spawn the acceptor plus `workers` handler threads on `listener`.
/// Returns the spawned handles; the threads run until the process exits.
pub fn serve<H: Handler>(
    listener: TcpListener,
    workers: usize,
    handler: Arc<H>,
) -> Vec<JoinHandle<()>> {
    serve_with_shutdown(listener, workers, handler, Arc::new(AtomicBool::new(false)))
}

/// [`serve`], but the acceptor exits once `stop` is set (checked per
/// accepted connection — wake it by connecting to the listener). Workers
/// finish their in-flight responses and exit when the accept channel
/// drops.
pub fn serve_with_shutdown<H: Handler>(
    listener: TcpListener,
    workers: usize,
    handler: Arc<H>,
    stop: Arc<AtomicBool>,
) -> Vec<JoinHandle<()>> {
    let workers = workers.max(1);
    let (tx, rx): (Sender<TcpStream>, Receiver<TcpStream>) = channel();
    let rx = Arc::new(Mutex::new(rx));
    let mut handles = Vec::with_capacity(workers + 1);
    for i in 0..workers {
        let rx = Arc::clone(&rx);
        let handler = Arc::clone(&handler);
        handles.push(
            std::thread::Builder::new()
                .name(format!("wham-serve-{i}"))
                .spawn(move || {
                    let mut ctx = handler.make_ctx();
                    loop {
                        // Hold the lock only to pop one connection.
                        let stream = match rx.lock().unwrap().recv() {
                            Ok(s) => s,
                            Err(_) => return, // acceptor gone
                        };
                        serve_connection(&*handler, &mut ctx, stream);
                    }
                })
                .expect("spawning service worker"),
        );
    }
    handles.push(
        std::thread::Builder::new()
            .name("wham-accept".to_string())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        return; // drops tx; workers drain and exit
                    }
                    match stream {
                        Ok(s) => {
                            if tx.send(s).is_err() {
                                return; // all workers gone
                            }
                        }
                        Err(_) => continue,
                    }
                }
            })
            .expect("spawning service acceptor"),
    );
    handles
}

fn serve_connection<H: Handler>(handler: &H, ctx: &mut H::Ctx, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let resp = match read_request(&stream) {
        Ok(req) => {
            // A panicking handler must cost one response, not one worker.
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handler.handle(ctx, &req)
            })) {
                Ok(resp) => resp,
                Err(p) => Response::error(
                    500,
                    &format!("handler panicked: {}", crate::util::panic_text(&p)),
                ),
            }
        }
        Err(ReadError::Timeout) => Response::error(408, "timed out reading request"),
        Err(ReadError::TooLarge(what)) => Response::error(413, what),
        Err(ReadError::Malformed(e)) => Response::error(400, &format!("malformed request: {e}")),
    };
    let _ = write_response(&stream, resp);
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

fn read_request(stream: &TcpStream) -> Result<Request, ReadError> {
    let bad = |msg: &str| ReadError::Malformed(msg.to_string());
    // Hard cap on total bytes read per request; an endless request line
    // hits the cap and errors instead of growing without bound.
    let mut reader = BufReader::new(stream.take((MAX_HEAD + MAX_BODY) as u64));
    let mut line = String::new();
    reader.read_line(&mut line).map_err(classify_io)?;
    let mut head_bytes = line.len();
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| bad("empty request line"))?.to_string();
    let target = parts.next().ok_or_else(|| bad("missing request target"))?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h).map_err(classify_io)? == 0 {
            return Err(bad("connection closed mid-headers"));
        }
        head_bytes += h.len();
        if head_bytes > MAX_HEAD {
            return Err(ReadError::TooLarge("request headers too large"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length =
                    v.trim().parse().map_err(|_| bad("unparseable content-length"))?;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(ReadError::TooLarge("request body too large"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(classify_io)?;
    let body = String::from_utf8(body).map_err(|_| bad("request body is not utf-8"))?;
    Ok(Request { method, path, query, body })
}

/// Adapter turning each `write` into one flushed HTTP chunk, so an SSE
/// frame reaches the client the moment the search emits it.
struct ChunkedWriter<'a> {
    stream: &'a TcpStream,
}

impl Write for ChunkedWriter<'_> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let mut s = self.stream;
        write!(s, "{:x}\r\n", buf.len())?;
        s.write_all(buf)?;
        s.write_all(b"\r\n")?;
        s.flush()?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        let mut s = self.stream;
        s.flush()
    }
}

fn write_response(mut stream: &TcpStream, resp: Response) -> std::io::Result<()> {
    let mut extra = String::new();
    for (k, v) in &resp.headers {
        extra.push_str(k);
        extra.push_str(": ");
        extra.push_str(v);
        extra.push_str("\r\n");
    }
    match resp.stream {
        Some(f) => {
            let head = format!(
                "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nCache-Control: no-cache\r\n{extra}Connection: close\r\n\r\n",
                resp.status,
                resp.reason(),
                resp.content_type,
            );
            stream.write_all(head.as_bytes())?;
            stream.flush()?;
            let mut w = ChunkedWriter { stream };
            // A panicking stream body must cost one connection, not one
            // worker (mirrors the handler's catch_unwind).
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                let _ = f(&mut w);
            }));
            stream.write_all(b"0\r\n\r\n")?;
            stream.flush()
        }
        None => {
            let head = format!(
                "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{extra}Connection: close\r\n\r\n",
                resp.status,
                resp.reason(),
                resp.content_type,
                resp.body.len()
            );
            stream.write_all(head.as_bytes())?;
            stream.write_all(resp.body.as_bytes())?;
            stream.flush()
        }
    }
}

/// Decode a `Transfer-Encoding: chunked` body already read to EOF.
fn dechunk(raw: &[u8]) -> std::io::Result<Vec<u8>> {
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let mut out = Vec::with_capacity(raw.len());
    let mut pos = 0usize;
    loop {
        let rest = &raw[pos..];
        let nl = rest
            .windows(2)
            .position(|w| w == b"\r\n")
            .ok_or_else(|| bad("chunked body: missing size line"))?;
        let size_line = std::str::from_utf8(&rest[..nl]).map_err(|_| bad("bad chunk size"))?;
        let size_hex = size_line.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_hex, 16).map_err(|_| bad("bad chunk size"))?;
        pos += nl + 2;
        if size == 0 {
            return Ok(out);
        }
        if pos + size > raw.len() {
            return Err(bad("truncated chunk"));
        }
        out.extend_from_slice(&raw[pos..pos + size]);
        pos += size + 2; // skip the chunk's trailing CRLF
        if pos > raw.len() {
            return Err(bad("truncated chunk terminator"));
        }
    }
}

/// Minimal blocking HTTP client for `wham client` and the tests: one
/// request over a fresh connection, returns `(status, body)` (chunked
/// bodies are decoded).
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> std::io::Result<(u16, String)> {
    let (status, _, body) = request_full(addr, method, path, body)?;
    Ok((status, body))
}

/// Like [`request`], also returning the response headers as lowercased
/// `(name, value)` pairs — admission-control callers read `retry-after`.
pub fn request_full(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> std::io::Result<(u16, Vec<(String, String)>, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    stream.flush()?;
    let mut raw = Vec::new();
    // The server closes the connection after one response.
    BufReader::new(stream).read_to_end(&mut raw)?;
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .ok_or_else(|| bad("no header break"))?;
    let head = std::str::from_utf8(&raw[..split]).map_err(|_| bad("non-utf8 head"))?;
    let resp_body = &raw[split + 4..];
    let mut lines = head.split("\r\n");
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("bad status line"))?;
    let mut headers = Vec::new();
    let mut chunked = false;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            let k = k.trim().to_ascii_lowercase();
            let v = v.trim().to_string();
            if k == "transfer-encoding" && v.eq_ignore_ascii_case("chunked") {
                chunked = true;
            }
            headers.push((k, v));
        }
    }
    let resp_body = if chunked { dechunk(resp_body)? } else { resp_body.to_vec() };
    let resp_body = String::from_utf8(resp_body).map_err(|_| bad("non-utf8 body"))?;
    Ok((status, headers, resp_body))
}

/// Streaming client: delivers each line of the response body to
/// `on_line` as it arrives (dechunked), without waiting for EOF — how
/// `wham jobs watch` follows an SSE stream. `on_line` returning `false`
/// stops reading early. Returns the HTTP status.
pub fn request_stream(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
    mut on_line: impl FnMut(&str) -> bool,
) -> std::io::Result<u16> {
    let mut stream = TcpStream::connect(addr)?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    stream.flush()?;
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("bad status line"))?;
    let mut chunked = false;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            return Err(bad("connection closed mid-headers"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("transfer-encoding")
                && v.trim().eq_ignore_ascii_case("chunked")
            {
                chunked = true;
            }
        }
    }
    let mut pending = String::new();
    let mut deliver = |pending: &mut String, on_line: &mut dyn FnMut(&str) -> bool| -> bool {
        while let Some(nl) = pending.find('\n') {
            let line: String = pending.drain(..=nl).collect();
            if !on_line(line.trim_end_matches(['\n', '\r'])) {
                return false;
            }
        }
        true
    };
    if chunked {
        loop {
            let mut size_line = String::new();
            if reader.read_line(&mut size_line)? == 0 {
                break;
            }
            let size_hex = size_line.trim().split(';').next().unwrap_or("").trim();
            if size_hex.is_empty() {
                continue;
            }
            let size = usize::from_str_radix(size_hex, 16).map_err(|_| bad("bad chunk size"))?;
            if size == 0 {
                break;
            }
            let mut chunk = vec![0u8; size + 2]; // data + CRLF
            reader.read_exact(&mut chunk)?;
            chunk.truncate(size);
            pending.push_str(&String::from_utf8_lossy(&chunk));
            if !deliver(&mut pending, &mut on_line) {
                return Ok(status);
            }
        }
    } else {
        loop {
            let mut l = String::new();
            if reader.read_line(&mut l)? == 0 {
                break;
            }
            pending.push_str(&l);
            if !deliver(&mut pending, &mut on_line) {
                return Ok(status);
            }
        }
    }
    if !pending.is_empty() {
        on_line(pending.trim_end_matches(['\n', '\r']));
    }
    Ok(status)
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl Handler for Echo {
        type Ctx = usize;
        fn make_ctx(&self) -> usize {
            0
        }
        fn handle(&self, ctx: &mut usize, req: &Request) -> Response {
            *ctx += 1;
            if req.path == "/stream" {
                return Response::stream(
                    "text/event-stream",
                    Box::new(|w: &mut dyn Write| {
                        for i in 0..3 {
                            write!(w, "data: frame-{i}\n\n")?;
                        }
                        Ok(())
                    }),
                );
            }
            if req.path == "/retry" {
                return Response::error_retry_after(429, "slow down", 7);
            }
            Response::json(format!(
                "{{\"method\":{},\"path\":{},\"body\":{},\"n\":{}}}",
                crate::util::json::esc(&req.method),
                crate::util::json::esc(&req.path),
                crate::util::json::esc(&req.body),
                ctx
            ))
        }
    }

    #[test]
    fn round_trip_get_and_post() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        serve(listener, 2, Arc::new(Echo));
        let (status, body) = request(addr, "GET", "/ping?x=1", None).unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.get("method").unwrap().as_str(), Some("GET"));
        assert_eq!(v.get("path").unwrap().as_str(), Some("/ping"));

        let (status, body) = request(addr, "POST", "/echo", Some("{\"k\":1}")).unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.get("body").unwrap().as_str(), Some("{\"k\":1}"));
    }

    #[test]
    fn concurrent_requests_all_answered() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        serve(listener, 4, Arc::new(Echo));
        let threads: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    request(addr, "POST", "/echo", Some(&format!("{{\"i\":{i}}}"))).unwrap()
                })
            })
            .collect();
        for t in threads {
            let (status, _) = t.join().unwrap();
            assert_eq!(status, 200);
        }
    }

    #[test]
    fn streaming_response_chunks_and_dechunks() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        serve(listener, 2, Arc::new(Echo));
        // Blocking client sees the whole dechunked body.
        let (status, body) = request(addr, "GET", "/stream", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "data: frame-0\n\ndata: frame-1\n\ndata: frame-2\n\n");
        // Streaming client sees the individual lines.
        let mut lines = Vec::new();
        let status = request_stream(addr, "GET", "/stream", None, |l| {
            lines.push(l.to_string());
            true
        })
        .unwrap();
        assert_eq!(status, 200);
        assert_eq!(lines.iter().filter(|l| l.starts_with("data: ")).count(), 3);
        // Early-stop after the first data line.
        let mut n = 0;
        request_stream(addr, "GET", "/stream", None, |l| {
            if l.starts_with("data: ") {
                n += 1;
            }
            n < 1
        })
        .unwrap();
        assert_eq!(n, 1);
    }

    #[test]
    fn retry_after_header_reaches_the_client() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        serve(listener, 1, Arc::new(Echo));
        let (status, headers, body) = request_full(addr, "GET", "/retry", None).unwrap();
        assert_eq!(status, 429);
        assert!(body.contains("slow down"));
        let retry = headers.iter().find(|(k, _)| k == "retry-after").map(|(_, v)| v.as_str());
        assert_eq!(retry, Some("7"));
    }

    #[test]
    fn oversized_body_and_headers_get_413() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        serve(listener, 1, Arc::new(Echo));
        // Declared body beyond the cap — rejected from the header alone.
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "POST /echo HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1).unwrap();
        s.flush().unwrap();
        let mut raw = String::new();
        BufReader::new(s).read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 413 "), "{raw}");

        // Header section beyond the cap.
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET /echo HTTP/1.1\r\n").unwrap();
        let filler = format!("X-Filler: {}\r\n", "y".repeat(8000));
        for _ in 0..10 {
            s.write_all(filler.as_bytes()).unwrap();
        }
        s.flush().unwrap();
        let mut raw = String::new();
        BufReader::new(s).read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 413 "), "{raw}");
    }

    #[test]
    fn io_timeouts_classify_as_408() {
        let timeout = std::io::Error::new(std::io::ErrorKind::TimedOut, "t");
        assert!(matches!(classify_io(timeout), ReadError::Timeout));
        let block = std::io::Error::new(std::io::ErrorKind::WouldBlock, "w");
        assert!(matches!(classify_io(block), ReadError::Timeout));
        let other = std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "e");
        assert!(matches!(classify_io(other), ReadError::Malformed(_)));
    }

    #[test]
    fn shutdown_flag_stops_the_acceptor() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let handles = serve_with_shutdown(listener, 1, Arc::new(Echo), Arc::clone(&stop));
        let (status, _) = request(addr, "GET", "/ping", None).unwrap();
        assert_eq!(status, 200);
        stop.store(true, Ordering::SeqCst);
        // Wake the acceptor; this connection is the last one served.
        let _ = TcpStream::connect(addr);
        for h in handles {
            h.join().unwrap();
        }
        // Connections after shutdown are refused or reset, never served.
        match request(addr, "GET", "/ping", None) {
            Ok((status, _)) => panic!("served {status} after shutdown"),
            Err(_) => {}
        }
    }
}
