//! Dependency-free HTTP/1.1 plumbing on `std::net` (the offline cache
//! has no tokio/hyper; blocking OS threads follow the same substitution
//! the [`crate::coordinator`] makes for the search fan-out).
//!
//! One bounded pool of worker threads serves all connections; each
//! worker owns per-thread state built by [`Handler::make_ctx`] — the
//! mining service puts its (non-`Sync`) cost backend there. Connections
//! are `Connection: close`: one request, one response, which keeps the
//! parser ~100 lines and is plenty for a mining-service request profile
//! where the work dwarfs connection setup.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Cap on request bodies (1 MiB) — mining requests are tiny JSON.
const MAX_BODY: usize = 1 << 20;
/// Cap on the request line + headers (64 KiB).
const MAX_HEAD: usize = 64 << 10;
/// Socket read/write timeout. Bounds how long an idle or trickling
/// client can pin a pool worker; compute time (searches) is unaffected
/// because it happens between the read and the write.
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// One parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without the query string (`/search`).
    pub path: String,
    /// Raw query string after `?`, possibly empty.
    pub query: String,
    pub body: String,
}

/// An HTTP response to be serialized.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub body: String,
    /// `Content-Type` header value. Everything in the API is JSON except
    /// `GET /metrics`, which serves the Prometheus text exposition.
    pub content_type: &'static str,
}

impl Response {
    /// 200 with a JSON body.
    pub fn json(body: impl Into<String>) -> Self {
        Self { status: 200, body: body.into(), content_type: "application/json" }
    }

    /// 200 with a Prometheus text-exposition body (`GET /metrics`).
    pub fn prometheus(body: impl Into<String>) -> Self {
        Self {
            status: 200,
            body: body.into(),
            content_type: "text/plain; version=0.0.4; charset=utf-8",
        }
    }

    /// An error with a `{"error": ...}` JSON body.
    pub fn error(status: u16, msg: &str) -> Self {
        Self {
            status,
            body: format!("{{\"error\":{}}}", crate::util::json::esc(msg)),
            content_type: "application/json",
        }
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            _ => "Internal Server Error",
        }
    }
}

/// Per-worker request handler. `make_ctx` runs *on* the worker thread, so
/// the context does not need to be `Send` — only the handler itself is
/// shared.
pub trait Handler: Send + Sync + 'static {
    type Ctx;
    fn make_ctx(&self) -> Self::Ctx;
    fn handle(&self, ctx: &mut Self::Ctx, req: &Request) -> Response;
}

/// Spawn the acceptor plus `workers` handler threads on `listener`.
/// Returns the spawned handles; the threads run until the process exits
/// (the service has no drain protocol yet — see ROADMAP).
pub fn serve<H: Handler>(
    listener: TcpListener,
    workers: usize,
    handler: Arc<H>,
) -> Vec<JoinHandle<()>> {
    let workers = workers.max(1);
    let (tx, rx): (Sender<TcpStream>, Receiver<TcpStream>) = channel();
    let rx = Arc::new(Mutex::new(rx));
    let mut handles = Vec::with_capacity(workers + 1);
    for i in 0..workers {
        let rx = Arc::clone(&rx);
        let handler = Arc::clone(&handler);
        handles.push(
            std::thread::Builder::new()
                .name(format!("wham-serve-{i}"))
                .spawn(move || {
                    let mut ctx = handler.make_ctx();
                    loop {
                        // Hold the lock only to pop one connection.
                        let stream = match rx.lock().unwrap().recv() {
                            Ok(s) => s,
                            Err(_) => return, // acceptor gone
                        };
                        serve_connection(&*handler, &mut ctx, stream);
                    }
                })
                .expect("spawning service worker"),
        );
    }
    handles.push(
        std::thread::Builder::new()
            .name("wham-accept".to_string())
            .spawn(move || {
                for stream in listener.incoming() {
                    match stream {
                        Ok(s) => {
                            if tx.send(s).is_err() {
                                return; // all workers gone
                            }
                        }
                        Err(_) => continue,
                    }
                }
            })
            .expect("spawning service acceptor"),
    );
    handles
}

fn serve_connection<H: Handler>(handler: &H, ctx: &mut H::Ctx, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let resp = match read_request(&stream) {
        Ok(req) => {
            // A panicking handler must cost one response, not one worker.
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handler.handle(ctx, &req)
            })) {
                Ok(resp) => resp,
                Err(p) => Response::error(
                    500,
                    &format!("handler panicked: {}", crate::util::panic_text(&p)),
                ),
            }
        }
        Err(e) => Response::error(400, &format!("malformed request: {e}")),
    };
    let _ = write_response(&stream, &resp);
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

fn read_request(stream: &TcpStream) -> std::io::Result<Request> {
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    // Hard cap on total bytes read per request; an endless request line
    // hits the cap and errors instead of growing without bound.
    let mut reader = BufReader::new(stream.take((MAX_HEAD + MAX_BODY) as u64));
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| bad("empty request line"))?.to_string();
    let target = parts.next().ok_or_else(|| bad("missing request target"))?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            return Err(bad("connection closed mid-headers"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length =
                    v.trim().parse().map_err(|_| bad("unparseable content-length"))?;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(bad("request body too large"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8(body).map_err(|_| bad("request body is not utf-8"))?;
    Ok(Request { method, path, query, body })
}

fn write_response(mut stream: &TcpStream, resp: &Response) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        resp.reason(),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(resp.body.as_bytes())?;
    stream.flush()
}

/// Minimal blocking HTTP client for `wham client` and the tests: one
/// request over a fresh connection, returns `(status, body)`.
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    stream.flush()?;
    let mut raw = String::new();
    // The server closes the connection after one response.
    BufReader::new(stream).read_to_string(&mut raw)?;
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let (head, resp_body) = raw.split_once("\r\n\r\n").ok_or_else(|| bad("no header break"))?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("bad status line"))?;
    Ok((status, resp_body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl Handler for Echo {
        type Ctx = usize;
        fn make_ctx(&self) -> usize {
            0
        }
        fn handle(&self, ctx: &mut usize, req: &Request) -> Response {
            *ctx += 1;
            Response::json(format!(
                "{{\"method\":{},\"path\":{},\"body\":{},\"n\":{}}}",
                crate::util::json::esc(&req.method),
                crate::util::json::esc(&req.path),
                crate::util::json::esc(&req.body),
                ctx
            ))
        }
    }

    #[test]
    fn round_trip_get_and_post() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        serve(listener, 2, Arc::new(Echo));
        let (status, body) = request(addr, "GET", "/ping?x=1", None).unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.get("method").unwrap().as_str(), Some("GET"));
        assert_eq!(v.get("path").unwrap().as_str(), Some("/ping"));

        let (status, body) = request(addr, "POST", "/echo", Some("{\"k\":1}")).unwrap();
        assert_eq!(status, 200);
        let v = crate::util::json::parse(&body).unwrap();
        assert_eq!(v.get("body").unwrap().as_str(), Some("{\"k\":1}"));
    }

    #[test]
    fn concurrent_requests_all_answered() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        serve(listener, 4, Arc::new(Echo));
        let threads: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    request(addr, "POST", "/echo", Some(&format!("{{\"i\":{i}}}"))).unwrap()
                })
            })
            .collect();
        for t in threads {
            let (status, _) = t.join().unwrap();
            assert_eq!(status, 200);
        }
    }
}
