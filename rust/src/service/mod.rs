//! `wham serve` — the long-running, concurrent design-mining service.
//!
//! The one-shot CLI re-evaluates every `<TC-Dim, VC-Width>` point from
//! scratch and discards the results on exit. This subsystem turns the
//! same engine into a server that *accumulates*: a bounded thread pool
//! ([`http`]) feeds JSON endpoints ([`api`]) whose searches run through
//! a request-coalescing queue ([`queue`]) and read/write a persistent,
//! fingerprint-keyed design database ([`cache`]). Repeat searches are
//! answered without a single scheduler invocation, identical concurrent
//! requests share one computation, and the accumulated top-k pools
//! warm-start the distributed global search.
//!
//! ```bash
//! wham serve --port 8484 --workers 8 --db designs.jsonl
//! wham client search --model bert-base
//! wham client status
//! ```

pub mod api;
pub mod cache;
pub mod http;
pub mod queue;

use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::{make_backend, BackendChoice};
use api::{Api, ServiceState};
use cache::DesignDb;

/// Configuration of one service instance.
pub struct ServeOptions {
    /// Handler threads (each owns a cost backend). Also the bound on
    /// concurrently-executing requests.
    pub workers: usize,
    /// JSONL design-database path; `None` keeps the database in memory.
    pub db_path: Option<PathBuf>,
    pub backend: BackendChoice,
}

impl Default for ServeOptions {
    fn default() -> Self {
        // Worker count follows the machine (the CLI's --workers/--jobs
        // default), not a magic constant.
        Self { workers: crate::util::default_jobs(), db_path: None, backend: BackendChoice::Auto }
    }
}

/// A started service (threads run detached until process exit).
pub struct ServerHandle {
    pub addr: SocketAddr,
    pub state: Arc<ServiceState>,
}

/// Start serving on an already-bound listener and return immediately —
/// the entry point tests use (bind port 0, read `addr` back).
pub fn start(listener: TcpListener, opts: ServeOptions) -> anyhow::Result<ServerHandle> {
    // Fail fast on an unusable backend choice (e.g. explicit PJRT with no
    // artifacts) instead of erroring per-request in every worker.
    drop(make_backend(opts.backend)?);
    let db = Arc::new(match &opts.db_path {
        Some(p) => DesignDb::open(p)?,
        None => DesignDb::in_memory(),
    });
    let workers = opts.workers.max(1);
    let addr = listener.local_addr()?;
    let state = Arc::new(ServiceState::new(db, opts.backend, workers));
    http::serve(listener, workers, Arc::new(Api { state: Arc::clone(&state) }));
    Ok(ServerHandle { addr, state })
}

/// Bind `addr`, print a banner, and serve until the process is killed.
pub fn serve_forever(addr: &str, opts: ServeOptions) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let workers = opts.workers.max(1);
    let db_desc = opts
        .db_path
        .as_ref()
        .map(|p| p.display().to_string())
        .unwrap_or_else(|| "in-memory".to_string());
    let handle = start(listener, opts)?;
    println!(
        "wham serve listening on http://{} (workers={workers}, db={db_desc}, {} designs loaded)",
        handle.addr,
        handle.state.db.stats().loaded,
    );
    println!(
        "endpoints: GET /models  POST /search  POST /evaluate  POST /common  POST /global  POST /cluster  GET /status  GET /metrics"
    );
    loop {
        std::thread::park();
    }
}
