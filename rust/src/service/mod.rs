//! `wham serve` — the long-running, concurrent design-mining service.
//!
//! The one-shot CLI re-evaluates every `<TC-Dim, VC-Width>` point from
//! scratch and discards the results on exit. This subsystem turns the
//! same engine into a server that *accumulates*: a bounded thread pool
//! ([`http`]) feeds JSON endpoints ([`api`]) whose searches run through
//! a request-coalescing queue ([`queue`]) and read/write a persistent,
//! fingerprint-keyed design database ([`cache`]). Repeat searches are
//! answered without a single scheduler invocation, identical concurrent
//! requests share one computation, and the accumulated top-k pools
//! warm-start the distributed global search.
//!
//! Long-running work has a second front door, the async job tier
//! ([`crate::jobs`]): `POST /jobs` answers with an id immediately, the
//! dispatcher mines on its own threads, and a crash-safe write-ahead log
//! (`--jobs-db`) resumes interrupted jobs on the next boot. SIGINT /
//! SIGTERM trigger a graceful drain instead of dropping in-flight work.
//!
//! ```bash
//! wham serve --port 8484 --workers 8 --db designs.jsonl --jobs-db jobs.jsonl
//! wham client search --model bert-base
//! wham jobs submit --model bert-base
//! wham client status
//! ```

pub mod api;
pub mod cache;
pub mod http;
pub mod queue;

use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::api::Session;
use crate::coordinator::{make_backend, BackendChoice};
use crate::cost::native::NativeCost;
use crate::jobs::store::JobStore;
use crate::jobs::{DrainSummary, JobManager, JobsOptions};
use crate::telemetry::log;
use crate::telemetry::tsdb::{Scraper, TsdbOptions};
use api::{Api, ServiceState};
use cache::DesignDb;

/// Configuration of one service instance.
pub struct ServeOptions {
    /// Handler threads (each owns a cost backend). Also the bound on
    /// concurrently-executing requests.
    pub workers: usize,
    /// JSONL design-database path; `None` keeps the database in memory.
    pub db_path: Option<PathBuf>,
    pub backend: BackendChoice,
    /// JSONL job write-ahead log; `None` keeps the job store in memory
    /// (jobs do not survive a restart).
    pub jobs_path: Option<PathBuf>,
    /// Async-job dispatcher configuration (workers, queue depth, quotas,
    /// retry policy).
    pub jobs: JobsOptions,
    /// Graceful-shutdown budget: how long running jobs get to finish
    /// before being re-queued for the next boot.
    pub drain_secs: u64,
    /// Chrome-trace snapshot target; when set, span tracing is enabled
    /// and the buffer is snapshotted periodically plus once at shutdown.
    pub trace_out: Option<PathBuf>,
    /// Metrics-history tier shape (scrape period, ring capacities) for
    /// the tsdb behind `/metrics/history`, `/dashboard`, and the alert
    /// engine. Tests shrink `fine_every` to drive alerts quickly.
    pub tsdb: TsdbOptions,
}

impl Default for ServeOptions {
    fn default() -> Self {
        // Worker count follows the machine (the CLI's --workers/--jobs
        // default), not a magic constant.
        Self {
            workers: crate::util::default_jobs(),
            db_path: None,
            backend: BackendChoice::Auto,
            jobs_path: None,
            jobs: JobsOptions::default(),
            drain_secs: 20,
            trace_out: None,
            tsdb: TsdbOptions::default(),
        }
    }
}

/// A started service (threads run detached until process exit or
/// [`ServerHandle::shutdown`]).
pub struct ServerHandle {
    pub addr: SocketAddr,
    pub state: Arc<ServiceState>,
    /// Set (and wake the acceptor with one connection) to stop accepting;
    /// [`ServerHandle::shutdown`] does both plus the drain.
    pub stop: Arc<AtomicBool>,
    /// The tsdb scrape loop; stopped (with a final flush) on shutdown.
    scraper: Mutex<Option<Scraper>>,
}

impl ServerHandle {
    /// Graceful shutdown: stop accepting HTTP connections, drain the job
    /// tier within `drain`, run the tsdb scraper's final flush,
    /// checkpoint the job log, and flush the design database. Idempotent.
    pub fn shutdown(&self, drain: Duration) -> DrainSummary {
        self.stop.store(true, Ordering::SeqCst);
        // The acceptor checks the flag per connection; wake it.
        let _ = std::net::TcpStream::connect(self.addr);
        let summary = self.state.jobs.drain(drain);
        // Stop the scraper after the drain so the drain itself is the
        // last thing the history records.
        if let Some(mut s) = self.scraper.lock().unwrap().take() {
            s.stop();
        }
        let _ = self.state.jobs.store().checkpoint();
        self.state.db.flush();
        summary
    }
}

/// Start serving on an already-bound listener and return immediately —
/// the entry point tests use (bind port 0, read `addr` back).
pub fn start(listener: TcpListener, opts: ServeOptions) -> anyhow::Result<ServerHandle> {
    // Fail fast on an unusable backend choice (e.g. explicit PJRT with no
    // artifacts) instead of erroring per-request in every worker.
    drop(make_backend(opts.backend)?);
    let db = Arc::new(match &opts.db_path {
        Some(p) => DesignDb::open(p)?,
        None => DesignDb::in_memory(),
    });
    let store = Arc::new(match &opts.jobs_path {
        Some(p) => JobStore::open(p)?,
        None => JobStore::in_memory(),
    });
    let workers = opts.workers.max(1);
    let backend_choice = opts.backend;
    let dispatcher_workers = opts.jobs.workers.max(1);
    let jobs = JobManager::start(store, opts.jobs.clone(), {
        let db = Arc::clone(&db);
        move || {
            // Mirrors `Api::make_ctx`: an explicit-PJRT failure here can
            // only race an artifact deletion — fall back, don't die.
            let backend =
                make_backend(backend_choice).unwrap_or_else(|_| Box::new(NativeCost));
            // Split the machine across the dispatcher workers so
            // concurrent jobs do not oversubscribe the cores.
            let fanout = (crate::util::default_jobs() / dispatcher_workers).max(1);
            Session::with_backend(backend).with_db(Arc::clone(&db)).with_jobs(fanout)
        }
    });
    let addr = listener.local_addr()?;
    crate::telemetry::process::init();
    let state =
        Arc::new(ServiceState::new(db, opts.backend, workers, jobs, opts.tsdb.clone()));
    let stop = Arc::new(AtomicBool::new(false));
    // The tsdb scrape loop: registry + this instance's Collect samples
    // into the bounded history, alert rules evaluated per tick.
    let scraper = Scraper::start(Arc::clone(&state.tsdb), Arc::clone(&state.alerts), {
        let state = Arc::clone(&state);
        Box::new(move |out| {
            use crate::telemetry::Collect;
            state.collect(out)
        })
    });
    http::serve_with_shutdown(
        listener,
        workers,
        Arc::new(Api { state: Arc::clone(&state) }),
        Arc::clone(&stop),
    );
    Ok(ServerHandle { addr, state, stop, scraper: Mutex::new(Some(scraper)) })
}

#[cfg(unix)]
mod signals {
    use std::sync::atomic::{AtomicBool, Ordering};

    /// Set by the SIGINT/SIGTERM handler; polled by [`super::serve_forever`].
    pub static SHUTDOWN: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_sig: i32) {
        // A store to a static atomic is async-signal-safe.
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    /// Install the handlers via the libc `signal(2)` symbol std already
    /// links on unix — no crate dependency needed.
    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }

    pub fn requested() -> bool {
        SHUTDOWN.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod signals {
    pub fn install() {}
    pub fn requested() -> bool {
        false
    }
}

/// Bind `addr`, print a banner, and serve until SIGINT/SIGTERM (then
/// drain gracefully) or, on platforms without signal handling, forever.
pub fn serve_forever(addr: &str, opts: ServeOptions) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let workers = opts.workers.max(1);
    let drain = Duration::from_secs(opts.drain_secs);
    let db_desc = opts
        .db_path
        .as_ref()
        .map(|p| p.display().to_string())
        .unwrap_or_else(|| "in-memory".to_string());
    let jobs_desc = opts
        .jobs_path
        .as_ref()
        .map(|p| p.display().to_string())
        .unwrap_or_else(|| "in-memory".to_string());
    let trace_out = opts.trace_out.clone();
    if let Some(path) = trace_out.clone() {
        // A server has no "end of run" to flush at, so snapshot the span
        // buffer periodically (writes are whole-file, so the file is
        // always a complete Chrome-trace document).
        crate::telemetry::trace::enable();
        log::info(
            "serve",
            "span tracing on; snapshotting every 5s",
            &[("out", &path.display())],
        );
        std::thread::spawn(move || loop {
            std::thread::sleep(Duration::from_secs(5));
            let _ = crate::telemetry::trace::write_to(&path);
        });
    }
    let handle = start(listener, opts)?;
    log::info(
        "serve",
        "listening",
        &[
            ("addr", &format!("http://{}", handle.addr)),
            ("workers", &workers),
            ("db", &db_desc),
            ("designs_loaded", &handle.state.db.stats().loaded),
            ("jobs_db", &jobs_desc),
        ],
    );
    let store = handle.state.jobs.store();
    if store.resumed() > 0 || store.skipped() > 0 {
        log::info(
            "serve",
            "job log replayed",
            &[("requeued", &store.resumed()), ("skipped", &store.skipped())],
        );
    }
    log::info(
        "serve",
        "endpoints: GET /models  POST /search  POST /evaluate  POST /common  POST /global  POST /cluster  POST /jobs  GET /jobs[/:id[/events]]  GET /db/export  POST /db/import  GET /status  GET /metrics  GET /metrics/history  GET /dashboard  GET /alerts/events  GET /profile",
        &[],
    );
    signals::install();
    while !signals::requested() {
        std::thread::sleep(Duration::from_millis(200));
    }
    log::info("serve", "shutdown signal received; draining jobs", &[("budget_s", &drain.as_secs())]);
    let summary = handle.shutdown(drain);
    if let Some(path) = &trace_out {
        let _ = crate::telemetry::trace::write_to(path);
    }
    log::info(
        "serve",
        "drained",
        &[
            ("completed", &summary.completed),
            ("requeued", &summary.requeued),
            ("queued_left", &summary.queued_left),
        ],
    );
    Ok(())
}
