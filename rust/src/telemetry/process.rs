//! Build info and process-level gauges for `/metrics`, the tsdb
//! scraper, and the dashboard header.
//!
//! * `wham_build_info{version=...,git_sha=...} 1` — the standard
//!   "info metric" idiom: a constant-1 gauge whose labels carry the
//!   build identity, joinable against any other series.
//! * `wham_process_uptime_seconds` — seconds since this module was
//!   first touched (process start for any binary that scrapes).
//! * `wham_process_resident_memory_bytes` — RSS from
//!   `/proc/self/statm` (second field × page size); 0 where procfs is
//!   unavailable so the series stays well-typed off Linux.
//! * `wham_process_threads` — live thread count from `/proc/self/task`.
//!
//! All values are read at scrape time; nothing here touches hot paths.

use std::sync::OnceLock;
use std::time::Instant;

use super::registry::{Collect, Sample};

/// Process start, pinned on first use. `wham serve` touches this at
/// boot so uptime measures the server, not the first scrape.
fn started() -> Instant {
    static STARTED: OnceLock<Instant> = OnceLock::new();
    *STARTED.get_or_init(Instant::now)
}

/// Pin the uptime epoch now (call once at process boot).
pub fn init() {
    let _ = started();
}

/// Build identity baked at compile time: crate version plus the git
/// sha when the build environment provides one (`WHAM_GIT_SHA`),
/// "unknown" otherwise — CI sets it, plain `cargo build` need not.
pub fn build_info() -> (&'static str, &'static str) {
    let version = env!("CARGO_PKG_VERSION");
    let sha = option_env!("WHAM_GIT_SHA").unwrap_or("unknown");
    (version, sha)
}

/// Resident set size in bytes from `/proc/self/statm`, or 0 when
/// procfs is unavailable (non-Linux, sandboxes).
pub fn rss_bytes() -> u64 {
    let statm = match std::fs::read_to_string("/proc/self/statm") {
        Ok(s) => s,
        Err(_) => return 0,
    };
    let pages: u64 = statm
        .split_whitespace()
        .nth(1)
        .and_then(|f| f.parse().ok())
        .unwrap_or(0);
    pages * page_size()
}

fn page_size() -> u64 {
    // No libc: derive from the kernel's own accounting. statm counts
    // pages and /proc/self/status VmRSS reports kB; 4096 is correct on
    // every target we build (x86-64/aarch64 linux default page size).
    4096
}

/// Live thread count from `/proc/self/task`, or 0 off Linux.
pub fn thread_count() -> u64 {
    match std::fs::read_dir("/proc/self/task") {
        Ok(entries) => entries.count() as u64,
        Err(_) => 0,
    }
}

/// The [`Collect`] source emitting all process samples; pass to
/// `render_prometheus` extras and the tsdb scraper.
pub struct ProcessMetrics;

impl Collect for ProcessMetrics {
    fn collect(&self, out: &mut Vec<Sample>) {
        let (version, sha) = build_info();
        out.push(Sample::Gauge {
            name: "wham_build_info".into(),
            help: "Build identity (constant 1; labels carry version and git sha)."
                .into(),
            labels: vec![
                ("version".into(), version.into()),
                ("git_sha".into(), sha.into()),
            ],
            value: 1.0,
        });
        out.push(Sample::Gauge {
            name: "wham_process_uptime_seconds".into(),
            help: "Seconds since process start.".into(),
            labels: vec![],
            value: started().elapsed().as_secs_f64(),
        });
        out.push(Sample::Gauge {
            name: "wham_process_resident_memory_bytes".into(),
            help: "Resident set size from /proc/self/statm (0 where procfs is unavailable)."
                .into(),
            labels: vec![],
            value: rss_bytes() as f64,
        });
        out.push(Sample::Gauge {
            name: "wham_process_threads".into(),
            help: "Live threads from /proc/self/task (0 where procfs is unavailable)."
                .into(),
            labels: vec![],
            value: thread_count() as f64,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_info_has_a_version() {
        let (version, sha) = build_info();
        assert!(!version.is_empty());
        assert!(!sha.is_empty());
    }

    #[test]
    fn process_metrics_emit_all_four_samples() {
        let mut out = Vec::new();
        ProcessMetrics.collect(&mut out);
        let names: Vec<&str> = out
            .iter()
            .map(|s| match s {
                Sample::Gauge { name, .. } => name.as_str(),
                _ => "",
            })
            .collect();
        assert_eq!(
            names,
            vec![
                "wham_build_info",
                "wham_process_uptime_seconds",
                "wham_process_resident_memory_bytes",
                "wham_process_threads"
            ]
        );
        // On Linux (CI and dev boxes) procfs gives real values.
        if std::path::Path::new("/proc/self/statm").exists() {
            assert!(rss_bytes() > 0, "rss must be nonzero under procfs");
            assert!(thread_count() > 0, "thread count must be nonzero under procfs");
        }
    }
}
