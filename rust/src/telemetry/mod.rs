//! `wham::telemetry` — structured tracing, sampling profiler, metrics
//! registry, leveled logs, and the search flight recorder (std-only,
//! zero-cost when disabled).
//!
//! Seven layers, one module:
//!
//! * [`trace`] — RAII spans (`span!("mcr_probe", tc = c.tc)`) with
//!   sampler-walkable per-thread span stacks and a bounded,
//!   lock-free-indexed event buffer serializing to
//!   Chrome-trace/Perfetto JSON. Enabled by `--trace-out` on
//!   `wham search|global|cluster|serve`. The span taxonomy covers the
//!   hot layers end to end: `annotate`, `schedule`, `mcr`,
//!   `mcr_probe`, `mcr_gallop`, `prune_batch`, `search_phase`,
//!   `global_stage`, `global_prune`, `strategy_screen`, `event_sim`.
//! * [`profile`] — a sampling profiler over those span stacks: a
//!   background thread snapshots every thread's open-span path at a
//!   configurable Hz into a weighted trie, rendered as folded stacks
//!   (`GET /profile`, flamegraph.pl/speedscope ready) or a top-k
//!   hottest-path table (`wham trace profile`).
//! * [`registry`] — named counters and log2-bucketed histograms plus
//!   scrape-time gauges/summaries. The formerly ad-hoc statics
//!   (`cost::backend_rows_total`, `sched::evals_total`,
//!   `cluster::events_total`) register here, the service's
//!   `GET /metrics` renders the Prometheus text exposition, and the
//!   benches snapshot it into `BENCH_*.json`.
//! * [`log`] — leveled structured records (NDJSON or TTY-pretty) with
//!   per-request/job correlation ids; `X-Wham-Request-Id` on every
//!   HTTP response greps straight to the matching log lines.
//! * [`recorder`] — the flight recorder: per-iteration critical-path
//!   attribution of the local search (conflicted op class, cores
//!   granted, score delta, cache hit/miss) in a bounded ring, attached
//!   to `SearchReply.explain` and printed by `wham trace explain`.
//! * [`tsdb`] — bounded-memory metrics *history*: a background scraper
//!   samples the registry into two-tier ring-buffer series (counter
//!   rates, gauges, windowed histogram quantiles) and evaluates
//!   declarative alert rules with fire/resolve hysteresis. Serves
//!   `GET /metrics/history`, `GET /dashboard`, `GET /alerts/events`,
//!   and `wham top`.
//! * [`process`] — build info (`wham_build_info`) and process gauges:
//!   uptime, RSS from `/proc/self/statm`, thread count.
//!
//! Everything here *observes*; nothing feeds back into search
//! decisions, so the bit-identical parity guarantees of the fast paths
//! are untouched.

pub mod log;
pub mod process;
pub mod profile;
pub mod recorder;
pub mod registry;
pub mod trace;
pub mod tsdb;

pub use recorder::{ExplainRecord, FlightRecorder};
pub use registry::{render_prometheus, snapshot_json, Collect, Counter, Histogram, Sample};
pub use trace::{span, Span};
