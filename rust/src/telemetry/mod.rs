//! `wham::telemetry` — structured tracing, the unified metrics
//! registry, and the search flight recorder (std-only, zero-cost when
//! disabled).
//!
//! Three layers, one module:
//!
//! * [`trace`] — RAII spans (`span!("mcr_probe", tc = c.tc)`) with
//!   thread-local span stacks and a bounded, lock-free-indexed event
//!   buffer serializing to Chrome-trace/Perfetto JSON. Enabled by
//!   `--trace-out` on `wham search|global|cluster|serve`. The span
//!   taxonomy covers the hot layers end to end: `annotate`,
//!   `schedule`, `mcr`, `mcr_probe`, `mcr_gallop`, `prune_batch`,
//!   `search_phase`, `global_stage`, `global_prune`,
//!   `strategy_screen`, `event_sim`.
//! * [`registry`] — named counters plus scrape-time gauges/summaries.
//!   The formerly ad-hoc statics (`cost::backend_rows_total`,
//!   `sched::evals_total`, `cluster::events_total`) register here, the
//!   service's `GET /metrics` renders the Prometheus text exposition,
//!   and the benches snapshot it into `BENCH_*.json`.
//! * [`recorder`] — the flight recorder: per-iteration critical-path
//!   attribution of the local search (conflicted op class, cores
//!   granted, score delta, cache hit/miss) in a bounded ring, attached
//!   to `SearchReply.explain` and printed by `wham trace explain`.
//!
//! Everything here *observes*; nothing feeds back into search
//! decisions, so the bit-identical parity guarantees of the fast paths
//! are untouched.

pub mod recorder;
pub mod registry;
pub mod trace;

pub use recorder::{ExplainRecord, FlightRecorder};
pub use registry::{render_prometheus, snapshot_json, Collect, Counter, Sample};
pub use trace::{span, Span};
