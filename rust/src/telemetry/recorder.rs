//! The search flight recorder: per-iteration critical-path attribution
//! for the local (per-workload) search.
//!
//! The paper's efficiency claim is *why*-shaped — MCR steers core
//! additions to the operators that actually conflict on the critical
//! path. The engine records, for every `<TC-Dim, VC-Width>` it
//! evaluates, which core classes were granted cores, which operator was
//! the last critical conflict, what the point scored, and whether the
//! design cache served it — into a bounded ring that rides
//! [`crate::search::engine::SearchResult::explain`], surfaces as the
//! optional `explain` section of a `SearchReply`, and prints via
//! `wham trace explain`. Recording is a few dozen bytes per evaluated
//! dims (bounded by [`FlightRecorder::DEFAULT_CAP`]) and never changes
//! search outcomes.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::cost::Dims;

/// Ring occupancy of the most recently finished recorder (records kept,
/// records shed), published when a search consumes its recorder —
/// surfaced as gauges by `GET /metrics` so a scrape shows whether the
/// last search's explain log was complete.
static LAST_RECORDS: AtomicU64 = AtomicU64::new(0);
static LAST_DROPPED: AtomicU64 = AtomicU64::new(0);

/// `(records, dropped)` of the most recently finalized flight recorder.
pub fn last_occupancy() -> (u64, u64) {
    (LAST_RECORDS.load(Ordering::Relaxed), LAST_DROPPED.load(Ordering::Relaxed))
}

/// One evaluated `<TC-Dim, VC-Width>` with its critical-path attribution.
#[derive(Debug, Clone, PartialEq)]
pub struct ExplainRecord {
    /// The dims evaluated.
    pub dims: Dims,
    /// Score of the point under the search metric.
    pub score: f64,
    /// Best score over the whole search *after* this evaluation.
    pub best: f64,
    /// Whether this point raised the running best.
    pub improved: bool,
    /// Served by the eval cache / design DB (attribution fields below
    /// are zero: no scheduler ran).
    pub cache_hit: bool,
    /// Greedy-scheduler (or B&B node) invocations this evaluation cost.
    pub evals: u64,
    /// Final `(num_tc, num_vc)` the MCR loop granted.
    pub cores: (u64, u64),
    /// Cores granted to resolve tensor / vector / fused-class conflicts
    /// (fused grants add a whole TC+VC unit each).
    pub grants: (u64, u64, u64),
    /// Name of the last operator whose critical conflict MCR resolved.
    pub conflict_op: Option<String>,
}

/// Bounded ring of [`ExplainRecord`]s: keeps the most recent `cap`
/// entries and counts what it sheds.
#[derive(Debug, Clone, Default)]
pub struct FlightRecorder {
    records: VecDeque<ExplainRecord>,
    cap: usize,
    dropped: usize,
}

impl FlightRecorder {
    /// Default ring capacity — a full two-phase dimension search of the
    /// Table-4 workloads evaluates fewer points than this, so the usual
    /// case is a complete record.
    pub const DEFAULT_CAP: usize = 256;

    /// A recorder keeping the most recent `cap` records.
    pub fn new(cap: usize) -> Self {
        Self { records: VecDeque::with_capacity(cap.min(Self::DEFAULT_CAP)), cap, dropped: 0 }
    }

    /// Append, shedding the oldest record when full.
    pub fn push(&mut self, r: ExplainRecord) {
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.records.len() == self.cap {
            self.records.pop_front();
            self.dropped += 1;
        }
        self.records.push_back(r);
    }

    /// Records in evaluation order (oldest surviving first).
    pub fn records(&self) -> impl Iterator<Item = &ExplainRecord> {
        self.records.iter()
    }

    /// Records shed by the ring (0 = the log is complete).
    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// Consume into a plain vector (evaluation order), publishing this
    /// recorder's occupancy for the `/metrics` gauges.
    pub fn into_records(self) -> Vec<ExplainRecord> {
        LAST_RECORDS.store(self.records.len() as u64, Ordering::Relaxed);
        LAST_DROPPED.store(self.dropped as u64, Ordering::Relaxed);
        self.records.into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: u64) -> ExplainRecord {
        ExplainRecord {
            dims: Dims { tc_x: i, tc_y: i, vc_w: i },
            score: i as f64,
            best: i as f64,
            improved: true,
            cache_hit: false,
            evals: i,
            cores: (1, 1),
            grants: (0, 0, 0),
            conflict_op: None,
        }
    }

    #[test]
    fn ring_keeps_most_recent_and_counts_shed() {
        let mut fr = FlightRecorder::new(2);
        fr.push(rec(1));
        fr.push(rec(2));
        fr.push(rec(3));
        assert_eq!(fr.dropped(), 1);
        let kept: Vec<u64> = fr.records().map(|r| r.dims.tc_x).collect();
        assert_eq!(kept, vec![2, 3]);
        assert_eq!(fr.into_records().len(), 2);
    }

    #[test]
    fn zero_cap_records_nothing() {
        let mut fr = FlightRecorder::new(0);
        fr.push(rec(1));
        assert_eq!(fr.dropped(), 1);
        assert_eq!(fr.records().count(), 0);
    }
}
