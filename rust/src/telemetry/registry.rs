//! The unified metrics registry: every process-wide counter the repo
//! used to keep as a loose `static AtomicU64` registers itself here on
//! first touch, so one scrape path ([`render_prometheus`]) and one
//! snapshot path ([`snapshot_json`], used by the benches) see them all.
//!
//! Design constraints, in order:
//! * **Hot-path cost is one relaxed atomic op.** [`Counter::add`] is
//!   called once per greedy-scheduler run; after the one-time
//!   registration (`Once` fast path is a single load) it is exactly the
//!   `fetch_add` the old ad-hoc statics paid.
//! * **No global init order.** Counters are `const`-constructed statics
//!   that lazily self-register — a module never has to call into the
//!   registry at startup, and a counter that is never touched simply
//!   does not appear in the scrape.
//! * **Scrape-time values stay scrape-time.** Derived gauges (DB
//!   hit-rate) and quantile summaries (`LatencyRing` p50/p95) are not
//!   stored here; their owners implement [`Collect`] and are passed to
//!   [`render_prometheus`] per scrape, which keeps per-instance service
//!   state out of the process-global namespace (tests start several
//!   services in one process).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};

use crate::util::json::Obj;

/// A process-wide monotonically increasing counter. Declare as a
/// `static`; it registers itself in the global registry on first use.
pub struct Counter {
    name: &'static str,
    help: &'static str,
    cell: AtomicU64,
    registered: Once,
}

impl Counter {
    /// A new unregistered counter (registration happens on first touch).
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Self { name, help, cell: AtomicU64::new(0), registered: Once::new() }
    }

    /// Prometheus metric name (`wham_*_total`).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// One-line help text for the exposition format.
    pub fn help(&self) -> &'static str {
        self.help
    }

    /// Add `n` (relaxed; the counters are statistics, not synchronization).
    pub fn add(&'static self, n: u64) {
        self.registered.call_once(|| register(self));
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&'static self) -> u64 {
        self.registered.call_once(|| register(self));
        self.cell.load(Ordering::Relaxed)
    }
}

/// Number of log2 buckets a [`Histogram`] keeps. Bucket 0 holds the
/// value 0; bucket `i` holds values in `[2^(i-1), 2^i)`; the last
/// bucket additionally absorbs everything larger (2^30 ticks ≈ 18
/// minutes at microsecond resolution — far beyond any latency we
/// track).
pub const HIST_BUCKETS: usize = 31;

/// A process-wide log2-bucketed histogram. Declare as a `static`; like
/// [`Counter`] it is `const`-constructible and lazily self-registers on
/// first observation, so untouched histograms never appear in a scrape.
///
/// Observations are raw integer "ticks" (microseconds for latencies,
/// milliseconds for queue waits); `scale` converts ticks to the
/// exported unit at render time, so bucket boundaries come out in
/// seconds without any floating point on the hot path. An observation
/// costs three relaxed `fetch_add`s after the `Once` fast path.
pub struct Histogram {
    name: &'static str,
    help: &'static str,
    /// Multiplier from ticks to the exported unit (e.g. `1e-6` for
    /// microsecond ticks exported as seconds).
    scale: f64,
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
    registered: Once,
}

impl Histogram {
    /// A new unregistered histogram (registration happens on first
    /// observation).
    pub const fn new(name: &'static str, help: &'static str, scale: f64) -> Self {
        Self {
            name,
            help,
            scale,
            buckets: [const { AtomicU64::new(0) }; HIST_BUCKETS],
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
            registered: Once::new(),
        }
    }

    /// Prometheus metric name (`wham_*_seconds`).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Bucket index for a raw tick value: 0 for 0, else bit length,
    /// clamped into the fixed bucket array.
    fn bucket_index(ticks: u64) -> usize {
        ((u64::BITS - ticks.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    /// Inclusive upper bound (`le`), in ticks, of cumulative bucket `i`:
    /// buckets `0..=i` hold exactly the observations `<= 2^i - 1`.
    fn le_ticks(i: usize) -> u64 {
        (1u64 << i) - 1
    }

    /// Record one observation of `ticks`.
    pub fn observe(&'static self, ticks: u64) {
        self.registered.call_once(|| register_histogram(self));
        self.buckets[Self::bucket_index(ticks)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ticks, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a duration in microsecond ticks (pair with `scale = 1e-6`
    /// so the exported unit is seconds).
    pub fn observe_micros(&'static self, d: std::time::Duration) {
        self.observe(d.as_micros() as u64);
    }

    /// RAII form of [`observe_micros`]: observes the guard's lifetime.
    pub fn start_timer(&'static self) -> HistTimer {
        HistTimer { hist: self, start: std::time::Instant::now() }
    }

    /// Observations recorded so far.
    pub fn count(&'static self) -> u64 {
        self.registered.call_once(|| register_histogram(self));
        self.count.load(Ordering::Relaxed)
    }

    /// Render this histogram as a scrape [`Sample::Histogram`]:
    /// cumulative `(le, count)` pairs in the exported unit, one pair per
    /// non-empty bucket (cumulative semantics make sparse buckets
    /// legal), plus sum and count.
    fn sample(&self) -> Sample {
        let mut buckets = Vec::new();
        let mut cumulative = 0u64;
        // The last bucket is the overflow bucket: its upper bound is
        // only honest as `+Inf`, so it contributes to the count but
        // never gets its own `le` line.
        for i in 0..HIST_BUCKETS - 1 {
            let n = self.buckets[i].load(Ordering::Relaxed);
            cumulative += n;
            if n > 0 {
                buckets.push((Self::le_ticks(i) as f64 * self.scale, cumulative));
            }
        }
        Sample::Histogram {
            name: self.name.to_string(),
            help: self.help.to_string(),
            labels: vec![],
            buckets,
            sum: self.sum.load(Ordering::Relaxed) as f64 * self.scale,
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// Bucket a window of raw tick observations into the cumulative log2
/// `(le, count)` pairs a [`Sample::Histogram`] wants, plus sum and
/// count in the exported unit. For scrape-time histograms built from
/// non-registered sources (e.g. the endpoint latency ring windows).
pub fn log2_buckets(ticks: impl Iterator<Item = u64>, scale: f64) -> (Vec<(f64, u64)>, f64, u64) {
    let mut counts = [0u64; HIST_BUCKETS];
    let mut sum = 0u64;
    let mut count = 0u64;
    for t in ticks {
        counts[Histogram::bucket_index(t)] += 1;
        sum += t;
        count += 1;
    }
    let mut buckets = Vec::new();
    let mut cumulative = 0u64;
    for (i, &n) in counts.iter().enumerate().take(HIST_BUCKETS - 1) {
        cumulative += n;
        if n > 0 {
            buckets.push((Histogram::le_ticks(i) as f64 * scale, cumulative));
        }
    }
    (buckets, sum as f64 * scale, count)
}

/// Guard returned by [`Histogram::start_timer`]; observes the elapsed
/// wall-clock (in microsecond ticks) when dropped.
pub struct HistTimer {
    hist: &'static Histogram,
    start: std::time::Instant,
}

impl Drop for HistTimer {
    fn drop(&mut self) {
        self.hist.observe_micros(self.start.elapsed());
    }
}

/// One scrape-time sample contributed by a [`Collect`] implementor.
#[derive(Debug, Clone)]
pub enum Sample {
    /// A monotone counter owned outside the registry (e.g. per-service
    /// request totals).
    Counter { name: String, help: String, labels: Vec<(String, String)>, value: u64 },
    /// A point-in-time value (e.g. the design-DB hit rate).
    Gauge { name: String, help: String, labels: Vec<(String, String)>, value: f64 },
    /// A quantile summary (the histogram-shaped export of
    /// [`crate::service::api::LatencyRing`]): `(quantile, value)` pairs
    /// plus an observation count.
    Summary {
        name: String,
        help: String,
        labels: Vec<(String, String)>,
        quantiles: Vec<(f64, f64)>,
        count: u64,
    },
    /// A bucketed distribution: cumulative `(le, count)` pairs (`+Inf`
    /// is implied by `count` and appended at render time) plus the sum
    /// of observations in the exported unit. Used both by registered
    /// [`Histogram`] statics and per-instance sources such as the
    /// endpoint latency rings.
    Histogram {
        name: String,
        help: String,
        labels: Vec<(String, String)>,
        buckets: Vec<(f64, u64)>,
        sum: f64,
        count: u64,
    },
}

/// Scrape-time metric source. Owners of non-static state (the service)
/// implement this and hand themselves to [`render_prometheus`].
pub trait Collect {
    /// Append this source's samples.
    fn collect(&self, out: &mut Vec<Sample>);
}

fn registry() -> &'static Mutex<Vec<&'static Counter>> {
    static REGISTRY: OnceLock<Mutex<Vec<&'static Counter>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn register(c: &'static Counter) {
    let mut v = registry().lock().unwrap();
    debug_assert!(
        v.iter().all(|e| e.name != c.name),
        "duplicate metric name registered: {}",
        c.name
    );
    v.push(c);
}

fn histogram_registry() -> &'static Mutex<Vec<&'static Histogram>> {
    static HISTOGRAMS: OnceLock<Mutex<Vec<&'static Histogram>>> = OnceLock::new();
    HISTOGRAMS.get_or_init(|| Mutex::new(Vec::new()))
}

fn register_histogram(h: &'static Histogram) {
    let mut v = histogram_registry().lock().unwrap();
    debug_assert!(
        v.iter().all(|e| e.name != h.name),
        "duplicate metric name registered: {}",
        h.name
    );
    v.push(h);
}

/// Scrape samples for every registered histogram, sorted by name.
pub fn histogram_samples() -> Vec<Sample> {
    let mut hs: Vec<&'static Histogram> =
        histogram_registry().lock().unwrap().iter().copied().collect();
    hs.sort_unstable_by_key(|h| h.name);
    hs.iter().map(|h| h.sample()).collect()
}

/// Snapshot of every registered counter, sorted by name.
pub fn counters() -> Vec<(&'static str, u64)> {
    let mut v: Vec<(&'static str, u64)> =
        registry().lock().unwrap().iter().map(|c| (c.name, c.cell.load(Ordering::Relaxed))).collect();
    v.sort_unstable_by_key(|&(n, _)| n);
    v
}

/// Value of one registered counter by name (test / bench convenience).
pub fn counter_value(name: &str) -> Option<u64> {
    registry()
        .lock()
        .unwrap()
        .iter()
        .find(|c| c.name == name)
        .map(|c| c.cell.load(Ordering::Relaxed))
}

fn label_str(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let body: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}={}", prom_quote(v))).collect();
    format!("{{{}}}", body.join(","))
}

/// Quote a label value per the exposition format (`\\`, `\"`, `\n`).
fn prom_quote(v: &str) -> String {
    let mut out = String::with_capacity(v.len() + 2);
    out.push('"');
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Escape a HELP line (`\\` and newline only, per the format spec).
fn prom_help(h: &str) -> String {
    h.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Format a sample value; Prometheus text accepts integer or float forms.
fn prom_num(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Render the Prometheus text exposition: every registered counter
/// (sorted by name) followed by the scrape-time samples from `extra`.
/// `# HELP`/`# TYPE` headers are emitted once per metric name even when
/// several labeled sample lines share it (the `LatencyRing` summaries).
pub fn render_prometheus(extra: &[&dyn Collect]) -> String {
    let mut out = String::new();
    {
        let reg = registry().lock().unwrap();
        let mut sorted: Vec<&'static Counter> = reg.iter().copied().collect();
        sorted.sort_unstable_by_key(|c| c.name);
        for c in sorted {
            out.push_str(&format!("# HELP {} {}\n", c.name, prom_help(c.help)));
            out.push_str(&format!("# TYPE {} counter\n", c.name));
            out.push_str(&format!("{} {}\n", c.name, c.cell.load(Ordering::Relaxed)));
        }
    }
    let mut samples = histogram_samples();
    for src in extra {
        src.collect(&mut samples);
    }
    let mut seen_header: Vec<String> = Vec::new();
    let mut header = |out: &mut String, name: &str, help: &str, kind: &str| {
        if !seen_header.iter().any(|h| h == name) {
            out.push_str(&format!("# HELP {name} {}\n", prom_help(help)));
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            seen_header.push(name.to_string());
        }
    };
    for s in &samples {
        match s {
            Sample::Counter { name, help, labels, value } => {
                header(&mut out, name, help, "counter");
                out.push_str(&format!("{name}{} {value}\n", label_str(labels)));
            }
            Sample::Gauge { name, help, labels, value } => {
                header(&mut out, name, help, "gauge");
                out.push_str(&format!("{name}{} {}\n", label_str(labels), prom_num(*value)));
            }
            Sample::Summary { name, help, labels, quantiles, count } => {
                header(&mut out, name, help, "summary");
                for &(q, v) in quantiles {
                    let mut ls = labels.clone();
                    ls.push(("quantile".to_string(), format!("{q}")));
                    out.push_str(&format!("{name}{} {}\n", label_str(&ls), prom_num(v)));
                }
                out.push_str(&format!("{name}_count{} {count}\n", label_str(labels)));
            }
            Sample::Histogram { name, help, labels, buckets, sum, count } => {
                header(&mut out, name, help, "histogram");
                for &(le, cumulative) in buckets {
                    let mut ls = labels.clone();
                    ls.push(("le".to_string(), prom_num(le)));
                    out.push_str(&format!("{name}_bucket{} {cumulative}\n", label_str(&ls)));
                }
                let mut ls = labels.clone();
                ls.push(("le".to_string(), "+Inf".to_string()));
                out.push_str(&format!("{name}_bucket{} {count}\n", label_str(&ls)));
                out.push_str(&format!("{name}_sum{} {}\n", label_str(labels), prom_num(*sum)));
                out.push_str(&format!("{name}_count{} {count}\n", label_str(labels)));
            }
        }
    }
    out
}

/// JSON snapshot of every registered counter (sorted by name) — the
/// benches embed this in their `BENCH_*.json` so counter trajectories
/// ride the existing artifacts.
pub fn snapshot_json() -> String {
    let mut o = Obj::new();
    for (name, value) in counters() {
        o = o.u64(name, value);
    }
    for s in histogram_samples() {
        if let Sample::Histogram { name, count, .. } = s {
            o = o.u64(&format!("{name}_count"), count);
        }
    }
    o.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    static TEST_A: Counter = Counter::new("wham_test_registry_a_total", "Test counter A.");
    static TEST_B: Counter = Counter::new("wham_test_registry_b_total", "Test counter B.");

    #[test]
    fn counters_register_on_first_touch_and_accumulate() {
        TEST_A.add(2);
        TEST_A.add(3);
        assert_eq!(TEST_A.get(), 5);
        assert_eq!(counter_value("wham_test_registry_a_total"), Some(5));
    }

    #[test]
    fn exposition_has_one_header_per_metric_and_sorted_counters() {
        TEST_A.add(1);
        TEST_B.add(1);
        struct Extra;
        impl Collect for Extra {
            fn collect(&self, out: &mut Vec<Sample>) {
                out.push(Sample::Gauge {
                    name: "wham_test_gauge".into(),
                    help: "A gauge.".into(),
                    labels: vec![],
                    value: 0.5,
                });
                out.push(Sample::Summary {
                    name: "wham_test_summary_seconds".into(),
                    help: "A summary.".into(),
                    labels: vec![("endpoint".into(), "/a".into())],
                    quantiles: vec![(0.5, 0.001), (0.95, 0.002)],
                    count: 7,
                });
                out.push(Sample::Summary {
                    name: "wham_test_summary_seconds".into(),
                    help: "A summary.".into(),
                    labels: vec![("endpoint".into(), "/b".into())],
                    quantiles: vec![(0.5, 0.003)],
                    count: 1,
                });
            }
        }
        let text = render_prometheus(&[&Extra]);
        assert!(text.contains("# TYPE wham_test_registry_a_total counter"), "{text}");
        assert!(text.contains("# HELP wham_test_gauge A gauge.\n# TYPE wham_test_gauge gauge"));
        assert!(text.contains("wham_test_gauge 0.5\n"));
        assert!(text
            .contains("wham_test_summary_seconds{endpoint=\"/a\",quantile=\"0.5\"} 0.001\n"));
        assert!(text.contains("wham_test_summary_seconds_count{endpoint=\"/b\"} 1\n"));
        // One TYPE header per metric name, even across labeled series.
        let type_lines: Vec<&str> =
            text.lines().filter(|l| l.starts_with("# TYPE wham_test_summary_seconds ")).collect();
        assert_eq!(type_lines.len(), 1, "{text}");
        // No duplicate metric names among TYPE headers.
        let mut names: Vec<&str> =
            text.lines().filter_map(|l| l.strip_prefix("# TYPE ")).map(|l| l.split(' ').next().unwrap()).collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(before, names.len());
    }

    #[test]
    fn snapshot_json_parses_and_includes_registered_counters() {
        TEST_A.add(1);
        let v = crate::util::json::parse(&snapshot_json()).unwrap();
        assert!(v.get("wham_test_registry_a_total").and_then(|x| x.as_u64()).unwrap() >= 1);
    }

    #[test]
    fn label_quoting_escapes_specials() {
        assert_eq!(prom_quote("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(prom_num(f64::INFINITY), "+Inf");
    }

    static TEST_H: Histogram =
        Histogram::new("wham_test_registry_hist_ticks", "Test histogram.", 1.0);

    #[test]
    fn histogram_buckets_are_log2_and_cumulative() {
        // Bucket 0 = {0}; bucket i = [2^(i-1), 2^i).
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(u64::MAX), HIST_BUCKETS - 1);

        for v in [0u64, 1, 2, 3, 4, 7, 8] {
            TEST_H.observe(v);
        }
        assert_eq!(TEST_H.count(), 7);
        let text = render_prometheus(&[]);
        // le lines are cumulative: 0→1, 1→2, 3→4, 7→6, 15→7, +Inf→7.
        assert!(text.contains("# TYPE wham_test_registry_hist_ticks histogram"), "{text}");
        assert!(text.contains("wham_test_registry_hist_ticks_bucket{le=\"0\"} 1\n"), "{text}");
        assert!(text.contains("wham_test_registry_hist_ticks_bucket{le=\"1\"} 2\n"), "{text}");
        assert!(text.contains("wham_test_registry_hist_ticks_bucket{le=\"3\"} 4\n"), "{text}");
        assert!(text.contains("wham_test_registry_hist_ticks_bucket{le=\"7\"} 6\n"), "{text}");
        assert!(text.contains("wham_test_registry_hist_ticks_bucket{le=\"15\"} 7\n"), "{text}");
        assert!(text.contains("wham_test_registry_hist_ticks_bucket{le=\"+Inf\"} 7\n"), "{text}");
        assert!(text.contains("wham_test_registry_hist_ticks_sum 25\n"), "{text}");
        assert!(text.contains("wham_test_registry_hist_ticks_count 7\n"), "{text}");
        // Snapshot carries the observation count.
        let v = crate::util::json::parse(&snapshot_json()).unwrap();
        assert_eq!(
            v.get("wham_test_registry_hist_ticks_count").and_then(|x| x.as_u64()),
            Some(7)
        );
    }
}
