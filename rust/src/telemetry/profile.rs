//! Sampling profiler over the span stacks.
//!
//! A background thread wakes at a configurable rate, snapshots every
//! live thread's open-span path via
//! [`crate::telemetry::trace::sample_stacks`], and accumulates the
//! observed paths into a weighted trie. The result renders two ways:
//!
//! * [`Profile::collapsed`] — folded-stack text (`a;b;c 42` per line),
//!   the format `flamegraph.pl` and speedscope ingest directly. Served
//!   by `GET /profile?seconds=N&hz=M`.
//! * [`Profile::top_paths`] / [`Profile::render_table`] — the k hottest
//!   span paths with self/total sample percentages, printed by
//!   `wham trace profile <model>`.
//!
//! Attaching the sampler flips the shared span gate
//! ([`trace::set_sampling`]), so threads maintain live stacks even when
//! event tracing is off; with no sampler attached the cost of a span
//! site is the usual single relaxed load. Only one sampler can be
//! attached at a time — concurrent `GET /profile` calls beyond the
//! first are refused rather than queued.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::registry::Counter;
use super::trace;

/// Samples taken (sampler wake-ups) since process start.
static SAMPLES_TAKEN: Counter = Counter::new(
    "wham_profile_samples_total",
    "Stack samples taken by the span profiler since process start.",
);

/// Profile sessions successfully attached since process start.
static SESSIONS_ATTACHED: Counter = Counter::new(
    "wham_profile_sessions_attached_total",
    "Profiler sessions successfully attached since process start.",
);

/// Attach attempts rejected because a sampler was already running
/// (the `GET /profile` 409 path, previously invisible in telemetry).
static SESSIONS_REJECTED: Counter = Counter::new(
    "wham_profile_sessions_rejected_total",
    "Profiler attach attempts rejected while another session was active.",
);

/// Process-wide "a sampler is attached" latch; enforces the
/// one-at-a-time rule.
static ATTACHED: AtomicBool = AtomicBool::new(false);

/// Whether a sampler is currently attached (the profiler-state gauge).
pub fn is_attached() -> bool {
    ATTACHED.load(Ordering::SeqCst)
}

/// Sampling rates are clamped to this range: below 1 Hz a profile
/// window collects nothing useful, above 1 kHz the sampler starts
/// contending with the threads it is watching.
pub const MIN_HZ: u32 = 1;
pub const MAX_HZ: u32 = 1000;

/// One node of the weighted path trie. `self_samples` counts samples
/// whose innermost frame landed exactly here; a node's *total* weight
/// is its own count plus all descendants', computed at render time.
#[derive(Default)]
struct Node {
    self_samples: u64,
    children: BTreeMap<&'static str, Node>,
}

impl Node {
    fn insert(&mut self, path: &[&'static str]) {
        match path.split_first() {
            None => self.self_samples += 1,
            Some((head, rest)) => self.children.entry(head).or_default().insert(rest),
        }
    }

    fn total(&self) -> u64 {
        self.self_samples + self.children.values().map(Node::total).sum::<u64>()
    }
}

/// One span path with its sample weights, as reported by
/// [`Profile::top_paths`].
#[derive(Debug, Clone, PartialEq)]
pub struct PathStat {
    /// Semicolon-joined span path, outermost first (`schedule;mcr_probe`).
    pub path: String,
    /// Samples whose innermost open span was exactly this path.
    pub self_samples: u64,
    /// Samples with this path as a prefix (self + descendants).
    pub total_samples: u64,
}

/// The aggregate of one sampling window.
pub struct Profile {
    /// Sampler wake-ups (each may observe zero or more threads).
    pub samples: u64,
    /// Effective sampling rate.
    pub hz: u32,
    /// Wall-clock length of the window.
    pub elapsed: Duration,
    root: Node,
}

impl Profile {
    /// Total weighted samples across all observed stacks.
    pub fn weight(&self) -> u64 {
        self.root.total()
    }

    /// Folded-stack text: one `path;leaf N` line per distinct path with
    /// nonzero self weight, sorted by path. Feed to `flamegraph.pl` or
    /// paste into speedscope.
    pub fn collapsed(&self) -> String {
        let mut out = String::new();
        let mut prefix: Vec<&'static str> = Vec::new();
        fn walk(node: &Node, prefix: &mut Vec<&'static str>, out: &mut String) {
            if node.self_samples > 0 && !prefix.is_empty() {
                out.push_str(&prefix.join(";"));
                out.push(' ');
                out.push_str(&node.self_samples.to_string());
                out.push('\n');
            }
            for (name, child) in &node.children {
                prefix.push(name);
                walk(child, prefix, out);
                prefix.pop();
            }
        }
        walk(&self.root, &mut prefix, &mut out);
        out
    }

    /// The `k` hottest span paths by self weight (ties broken by total,
    /// then path), with totals for context.
    pub fn top_paths(&self, k: usize) -> Vec<PathStat> {
        let mut all = Vec::new();
        let mut prefix: Vec<&'static str> = Vec::new();
        fn walk(node: &Node, prefix: &mut Vec<&'static str>, all: &mut Vec<PathStat>) {
            if !prefix.is_empty() {
                all.push(PathStat {
                    path: prefix.join(";"),
                    self_samples: node.self_samples,
                    total_samples: node.total(),
                });
            }
            for (name, child) in &node.children {
                prefix.push(name);
                walk(child, prefix, all);
                prefix.pop();
            }
        }
        walk(&self.root, &mut prefix, &mut all);
        all.sort_by(|a, b| {
            b.self_samples
                .cmp(&a.self_samples)
                .then(b.total_samples.cmp(&a.total_samples))
                .then(a.path.cmp(&b.path))
        });
        all.truncate(k);
        all
    }

    /// Human-readable top-k table (path, self%, total%, samples).
    /// Percentages are of the total weighted samples in the window.
    pub fn render_table(&self, k: usize) -> String {
        let weight = self.weight().max(1) as f64;
        let mut t = crate::util::table::Table::new(["span path", "self%", "total%", "self", "total"]);
        for p in self.top_paths(k) {
            t.row([
                p.path.clone(),
                format!("{:.1}", p.self_samples as f64 * 100.0 / weight),
                format!("{:.1}", p.total_samples as f64 * 100.0 / weight),
                p.self_samples.to_string(),
                p.total_samples.to_string(),
            ]);
        }
        t.render()
    }
}

/// A running sampler. Obtain with [`attach`]; call [`stop`](Sampler::stop)
/// to detach and collect the [`Profile`]. Dropping without `stop` also
/// detaches cleanly.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<(Node, u64)>>,
    hz: u32,
    started: Instant,
}

/// Attach the process-wide sampler at `hz` (clamped to
/// [`MIN_HZ`]..=[`MAX_HZ`]). Fails if a sampler is already attached.
pub fn attach(hz: u32) -> Result<Sampler, &'static str> {
    if ATTACHED.swap(true, Ordering::SeqCst) {
        SESSIONS_REJECTED.add(1);
        return Err("a profiler is already attached");
    }
    SESSIONS_ATTACHED.add(1);
    let hz = hz.clamp(MIN_HZ, MAX_HZ);
    trace::set_sampling(true);
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let period = Duration::from_secs_f64(1.0 / f64::from(hz));
    let join = std::thread::Builder::new()
        .name("wham-profiler".into())
        .spawn(move || {
            let mut root = Node::default();
            let mut samples = 0u64;
            let mut next = Instant::now() + period;
            while !stop2.load(Ordering::Relaxed) {
                for (_tid, frames) in trace::sample_stacks() {
                    root.insert(&frames);
                }
                samples += 1;
                SAMPLES_TAKEN.add(1);
                let now = Instant::now();
                if next > now {
                    std::thread::sleep(next - now);
                }
                next += period;
            }
            (root, samples)
        })
        .expect("spawn profiler thread");
    Ok(Sampler { stop, join: Some(join), hz, started: Instant::now() })
}

impl Sampler {
    /// Detach the sampler and return the window's aggregate.
    pub fn stop(mut self) -> Profile {
        let (root, samples) = self.halt();
        Profile { samples, hz: self.hz, elapsed: self.started.elapsed(), root }
    }

    fn halt(&mut self) -> (Node, u64) {
        self.stop.store(true, Ordering::SeqCst);
        let out = match self.join.take() {
            Some(j) => j.join().unwrap_or_default(),
            None => Default::default(),
        };
        trace::set_sampling(false);
        ATTACHED.store(false, Ordering::SeqCst);
        out
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        if self.join.is_some() {
            self.halt();
        }
    }
}

/// Sample for `window` at `hz` and return the profile — the
/// `GET /profile` implementation. Blocks the calling thread for the
/// window; the sampler itself runs on its own thread.
pub fn profile_for(window: Duration, hz: u32) -> Result<Profile, &'static str> {
    let sampler = attach(hz)?;
    std::thread::sleep(window);
    Ok(sampler.stop())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile_of(paths: &[&[&'static str]]) -> Profile {
        let mut root = Node::default();
        for p in paths {
            root.insert(p);
        }
        Profile { samples: paths.len() as u64, hz: 99, elapsed: Duration::ZERO, root }
    }

    #[test]
    fn trie_weights_and_collapsed_output() {
        let p = profile_of(&[
            &["sched"],
            &["sched", "probe"],
            &["sched", "probe"],
            &["sim"],
        ]);
        assert_eq!(p.weight(), 4);
        let collapsed = p.collapsed();
        let mut lines: Vec<&str> = collapsed.lines().collect();
        lines.sort();
        assert_eq!(lines, vec!["sched 1", "sched;probe 2", "sim 1"]);
    }

    #[test]
    fn top_paths_rank_by_self_with_totals() {
        let p = profile_of(&[
            &["sched"],
            &["sched", "probe"],
            &["sched", "probe"],
            &["sim"],
        ]);
        let top = p.top_paths(10);
        assert_eq!(top[0].path, "sched;probe");
        assert_eq!(top[0].self_samples, 2);
        assert_eq!(top[0].total_samples, 2);
        // "sched" has self 1 but total 3 (itself + probe's two).
        let sched = top.iter().find(|s| s.path == "sched").unwrap();
        assert_eq!((sched.self_samples, sched.total_samples), (1, 3));
        // Table renders without panicking and mentions the hot path.
        assert!(p.render_table(5).contains("sched;probe"));
    }

    #[test]
    fn only_one_sampler_attaches() {
        // Serialize with anything else touching the global latch.
        let first = match attach(100) {
            Ok(s) => s,
            Err(_) => return, // another test holds it; nothing to check
        };
        assert!(attach(100).is_err());
        let prof = first.stop();
        assert_eq!(prof.hz, 100);
        // Released: attaching again works.
        attach(50).unwrap().stop();
    }
}
