//! Span-based tracing with Chrome-trace/Perfetto output.
//!
//! `let _s = span!("mcr_probe", tc = cand.tc);` opens an RAII span: the
//! guard pushes onto a per-thread span stack (so nesting depth is
//! queryable and Perfetto renders proper flame nesting per thread) and,
//! on drop, records one complete event into a process-global bounded
//! buffer. Serialization ([`chrome_json`] / [`write_to`]) produces the
//! Chrome trace-event JSON array the per-op `wham trace` command already
//! emits ([`crate::report::trace::chrome_trace`]), so both load in
//! <https://ui.perfetto.dev>.
//!
//! The per-thread stacks are shared, not thread-local-only: each thread
//! lazily registers an `Arc` handle in a process-global registry so the
//! sampling profiler ([`crate::telemetry::profile`]) can walk every
//! thread's open-span path from its own sampler thread. The stack mutex
//! is uncontended in the common case — only the owning thread and an
//! attached sampler (at ~100 Hz) ever touch it.
//!
//! Cost model:
//! * **Inactive (default):** [`span`] is one relaxed atomic load and a
//!   branch — the guard holds `None`, `arg` and `Drop` no-op. The <2%
//!   hot-path budget of the observability PRs rides on this. "Inactive"
//!   means neither tracing nor a sampler is on: both share the single
//!   `STATE` gate.
//! * **Tracing:** two `Instant::now()` calls plus a lock-free buffer
//!   append — the write index is reserved with a single `fetch_add`, and
//!   the payload store takes an uncontended per-slot lock (no thread
//!   ever blocks on another's slot). When the buffer is full, events
//!   are dropped and counted in `wham_trace_events_dropped_total`
//!   rather than grown without bound.
//! * **Sampling only:** stack push/pop under an uncontended mutex; no
//!   events are recorded, so the buffer and its drop accounting are
//!   untouched.
//!
//! Tracing never changes search outcomes: spans only observe, and the
//! parity suites (`hotpath_parity`, `parallel_*_match_serial`) run with
//! it both off and on in `rust/tests/telemetry.rs`.

use std::sync::atomic::{AtomicU32, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::Instant;

use super::registry::Counter;
use crate::util::json::{esc, Obj};

/// Buffer capacity in events (~6 MiB fully populated). A smoke search
/// emits a few thousand events; deep traces drop the tail and say so.
pub(crate) const CAP: usize = 1 << 16;

/// Bit in [`STATE`]: record complete events into the buffer.
const TRACING: u8 = 1 << 0;
/// Bit in [`STATE`]: a sampler is attached and wants live stacks.
const SAMPLING: u8 = 1 << 1;

/// The single hot-path gate. `span()` takes one relaxed load; zero
/// means "do nothing at all".
static STATE: AtomicU8 = AtomicU8::new(0);
static NEXT_TID: AtomicU32 = AtomicU32::new(1);

/// Events recorded into the trace buffer since process start.
static EVENTS_RECORDED: Counter =
    Counter::new("wham_trace_events_total", "Trace events recorded into the span buffer.");
/// Events dropped because the bounded buffer was full.
static EVENTS_DROPPED: Counter = Counter::new(
    "wham_trace_events_dropped_total",
    "Trace events dropped because the bounded span buffer was full.",
);

/// Force registration of the drop counter so `/metrics` shows the
/// (usually zero) drop count before the first overflow, and return it.
pub fn events_dropped_total() -> u64 {
    EVENTS_DROPPED.add(0);
    EVENTS_DROPPED.get()
}

/// Force registration of the recorded-events counter (see
/// [`events_dropped_total`]) and return it.
pub fn events_recorded_total() -> u64 {
    EVENTS_RECORDED.add(0);
    EVENTS_RECORDED.get()
}

#[derive(Debug, Clone)]
struct Event {
    name: &'static str,
    tid: u32,
    ts_us: u64,
    dur_us: u64,
    /// Pre-rendered `"key":"value"` pairs, comma-joined (empty = none).
    args: String,
}

struct Buffer {
    /// Slot locks are uncontended by construction: each index is owned
    /// by exactly the thread that reserved it from `cursor`.
    slots: Vec<Mutex<Option<Event>>>,
    cursor: AtomicUsize,
}

fn buffer() -> &'static Buffer {
    static BUFFER: OnceLock<Buffer> = OnceLock::new();
    BUFFER.get_or_init(|| Buffer {
        slots: (0..CAP).map(|_| Mutex::new(None)).collect(),
        cursor: AtomicUsize::new(0),
    })
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// One thread's open-span path, shared so the sampler can read it from
/// another thread. The owning thread pushes/pops; the mutex is
/// effectively uncontended (see module docs).
struct ThreadStack {
    tid: u32,
    frames: Mutex<Vec<&'static str>>,
}

fn thread_registry() -> &'static Mutex<Vec<Weak<ThreadStack>>> {
    static THREADS: OnceLock<Mutex<Vec<Weak<ThreadStack>>>> = OnceLock::new();
    THREADS.get_or_init(|| Mutex::new(Vec::new()))
}

fn register_thread() -> Arc<ThreadStack> {
    let stack = Arc::new(ThreadStack {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        frames: Mutex::new(Vec::new()),
    });
    let mut reg = thread_registry().lock().unwrap();
    // Exited threads leave dead weak handles behind; prune on the slow
    // (once-per-thread) path so the registry stays bounded.
    reg.retain(|w| w.strong_count() > 0);
    reg.push(Arc::downgrade(&stack));
    stack
}

thread_local! {
    static LOCAL: Arc<ThreadStack> = register_thread();
}

/// Snapshot every live thread's current open-span path, innermost last.
/// Empty stacks (idle threads) are skipped. This is the sampler's view;
/// it never blocks a working thread for longer than one push/pop.
pub(crate) fn sample_stacks() -> Vec<(u32, Vec<&'static str>)> {
    let reg = thread_registry().lock().unwrap();
    let mut out = Vec::new();
    for weak in reg.iter() {
        let Some(stack) = weak.upgrade() else { continue };
        let frames = stack.frames.lock().unwrap().clone();
        if !frames.is_empty() {
            out.push((stack.tid, frames));
        }
    }
    out
}

/// Turn tracing on (idempotent). Allocates the buffer and pins the
/// trace epoch on first call.
pub fn enable() {
    epoch();
    buffer();
    STATE.fetch_or(TRACING, Ordering::SeqCst);
}

/// Turn tracing off; already-recorded events stay in the buffer.
pub fn disable() {
    STATE.fetch_and(!TRACING, Ordering::SeqCst);
}

/// Whether spans are currently being recorded.
pub fn is_enabled() -> bool {
    STATE.load(Ordering::Relaxed) & TRACING != 0
}

/// Flip the sampler bit: while set, spans maintain live stacks even
/// with tracing off. Called only by [`crate::telemetry::profile`].
pub(crate) fn set_sampling(on: bool) {
    if on {
        STATE.fetch_or(SAMPLING, Ordering::SeqCst);
    } else {
        STATE.fetch_and(!SAMPLING, Ordering::SeqCst);
    }
}

/// Current span-nesting depth on this thread (0 when spans are inactive
/// or no span is open) — the `Progress::depth` source.
pub fn depth() -> usize {
    if STATE.load(Ordering::Relaxed) == 0 {
        return 0;
    }
    LOCAL.with(|s| s.frames.lock().unwrap().len())
}

/// Drop all buffered events (test isolation; callers serialize).
pub fn reset() {
    let b = buffer();
    let n = b.cursor.swap(0, Ordering::SeqCst).min(CAP);
    for slot in &b.slots[..n] {
        *slot.lock().unwrap() = None;
    }
}

fn record(ev: Event) {
    let b = buffer();
    let i = b.cursor.fetch_add(1, Ordering::Relaxed);
    if i < CAP {
        *b.slots[i].lock().unwrap() = Some(ev);
        EVENTS_RECORDED.add(1);
    } else {
        EVENTS_DROPPED.add(1);
    }
}

/// An open span. Created by [`span`] (or the `span!` macro); records one
/// complete trace event when dropped. Holds `None` when spans are
/// inactive (no tracing, no sampler).
pub struct Span(Option<ActiveSpan>);

struct ActiveSpan {
    name: &'static str,
    start: Instant,
    args: String,
    /// Record a buffer event on drop (tracing was on at open time).
    /// False when only a sampler is attached.
    record: bool,
}

/// Open a span named `name` on this thread. Binding matters:
/// `let _span = span("x");` keeps it open for the scope — a bare `_`
/// pattern would drop it immediately.
pub fn span(name: &'static str) -> Span {
    let state = STATE.load(Ordering::Relaxed);
    if state == 0 {
        return Span(None);
    }
    LOCAL.with(|s| s.frames.lock().unwrap().push(name));
    Span(Some(ActiveSpan {
        name,
        start: Instant::now(),
        args: String::new(),
        record: state & TRACING != 0,
    }))
}

impl Span {
    /// Attach a key/value attribute (rendered into the event's `args`
    /// object). No-op — including the `Display` formatting — when
    /// spans are inactive.
    pub fn arg(mut self, key: &str, value: impl std::fmt::Display) -> Self {
        if let Some(a) = self.0.as_mut() {
            if a.record {
                if !a.args.is_empty() {
                    a.args.push(',');
                }
                a.args.push_str(&esc(key));
                a.args.push(':');
                a.args.push_str(&esc(&value.to_string()));
            }
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(a) = self.0.take() else { return };
        let tid = LOCAL.with(|s| {
            s.frames.lock().unwrap().pop();
            s.tid
        });
        if !a.record {
            return;
        }
        let dur = a.start.elapsed();
        let ts = a.start.saturating_duration_since(epoch());
        record(Event {
            name: a.name,
            tid,
            ts_us: ts.as_micros() as u64,
            dur_us: dur.as_micros() as u64,
            args: a.args,
        });
    }
}

/// Open a span: `span!("name")` or `span!("name", key = value, ...)`.
/// Attribute values are formatted with `Display`, only when tracing is
/// enabled.
#[macro_export]
macro_rules! span {
    ($name:literal) => {
        $crate::telemetry::trace::span($name)
    };
    ($name:literal, $($k:ident = $v:expr),+ $(,)?) => {
        $crate::telemetry::trace::span($name)$(.arg(stringify!($k), $v))+
    };
}

/// Snapshot of the buffered events in record order.
fn snapshot() -> Vec<Event> {
    let b = buffer();
    let n = b.cursor.load(Ordering::SeqCst).min(CAP);
    b.slots[..n].iter().filter_map(|s| s.lock().unwrap().clone()).collect()
}

/// Number of events currently buffered.
pub fn event_count() -> usize {
    let b = buffer();
    b.cursor.load(Ordering::SeqCst).min(CAP)
}

/// Serialize the buffer as a Chrome trace-event JSON array (complete
/// `"ph":"X"` events; open <https://ui.perfetto.dev> and drop the file
/// in). Same top-level shape as [`crate::report::trace::chrome_trace`].
pub fn chrome_json() -> String {
    let events = snapshot();
    let mut out = String::with_capacity(events.len() * 96 + 2);
    out.push('[');
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let mut o = Obj::new()
            .str("name", e.name)
            .str("cat", "wham")
            .str("ph", "X")
            .u64("ts", e.ts_us)
            .u64("dur", e.dur_us)
            .u64("pid", 1)
            .u64("tid", u64::from(e.tid));
        if !e.args.is_empty() {
            o = o.raw("args", &format!("{{{}}}", e.args));
        }
        out.push_str(&o.finish());
    }
    out.push(']');
    out
}

/// Write [`chrome_json`] to `path` (the `--trace-out` sink).
pub fn write_to(path: &std::path::Path) -> std::io::Result<()> {
    std::fs::write(path, chrome_json())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The buffer and the enabled flag are process-global; tests in this
    // module (and the integration suite) serialize through this lock.
    static GUARD: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = GUARD.lock().unwrap();
        disable();
        reset();
        {
            let _s = span("never").arg("k", 1);
        }
        assert_eq!(event_count(), 0);
        assert_eq!(depth(), 0);
    }

    #[test]
    fn spans_nest_and_serialize() {
        let _g = GUARD.lock().unwrap();
        enable();
        reset();
        {
            let _outer = span("outer").arg("model", "bert");
            assert_eq!(depth(), 1);
            {
                let _inner = crate::span!("inner", k = 42);
                assert_eq!(depth(), 2);
            }
            assert_eq!(depth(), 1);
        }
        disable();
        assert_eq!(event_count(), 2);
        let v = crate::util::json::parse(&chrome_json()).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        // Inner drops first; both are complete events on the same tid.
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("inner"));
        assert_eq!(arr[1].get("name").unwrap().as_str(), Some("outer"));
        assert_eq!(arr[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(arr[0].get("tid").unwrap().as_u64(), arr[1].get("tid").unwrap().as_u64());
        assert_eq!(
            arr[1].get("args").unwrap().get("model").unwrap().as_str(),
            Some("bert")
        );
    }

    #[test]
    fn overflow_drops_instead_of_growing() {
        let _g = GUARD.lock().unwrap();
        enable();
        reset();
        // Simulate a full buffer by pushing the cursor to the cap.
        buffer().cursor.store(CAP, Ordering::SeqCst);
        let before = EVENTS_DROPPED.get();
        drop(span("overflow"));
        assert_eq!(EVENTS_DROPPED.get(), before + 1);
        disable();
        reset();
    }

    #[test]
    fn sampling_maintains_stacks_without_recording() {
        let _g = GUARD.lock().unwrap();
        disable();
        reset();
        set_sampling(true);
        {
            let _outer = span("outer");
            let _inner = span("inner");
            assert_eq!(depth(), 2);
            let stacks = sample_stacks();
            let mine = stacks
                .iter()
                .find(|(_, f)| f == &vec!["outer", "inner"])
                .expect("sampler sees this thread's stack");
            assert!(mine.0 > 0);
        }
        assert_eq!(depth(), 0);
        set_sampling(false);
        // No sampler, no tracing: nothing was recorded, spans are free.
        assert_eq!(event_count(), 0);
        drop(span("gone"));
        assert_eq!(event_count(), 0);
    }
}
