//! Leveled structured logging with request/job correlation.
//!
//! Std-only, one line per record. Two output formats:
//!
//! * **NDJSON** (the default when stderr is not a TTY, and always for
//!   `--log-out FILE`): `{"ts":..,"level":"info","target":"serve",
//!   "msg":"...","corr":"r-..","key":"value",...}` — greppable by the
//!   correlation id every HTTP response carries in
//!   `X-Wham-Request-Id`.
//! * **Pretty** (stderr on a TTY): `12:03:07 INFO  serve listening ...
//!   key=value [r-..]` for humans watching `wham serve`.
//!
//! A record is dropped before any formatting happens when its level is
//! below the configured threshold ([`enabled`] is one relaxed load).
//!
//! **Correlation:** [`CorrScope`] binds an id to the current thread for
//! its lifetime; every record emitted while the scope is live carries
//! it. `service/api.rs` opens a scope per HTTP request, the job workers
//! open one per job attempt, so one grep connects the access log, the
//! job lifecycle, and the WAL.
//!
//! Tests swap the sink for an in-memory buffer with [`capture`]; the
//! whole module is process-global, so tests that assert on output
//! serialize just like the trace-buffer tests do.

use std::cell::RefCell;
use std::io::{IsTerminal, Write};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Obj;

/// Record severity, ordered: `Debug < Info < Warn < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    /// Lowercase wire label (`"info"`).
    pub fn label(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }

    /// Parse a `--log-level` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }
}

/// Threshold; records below it are dropped unformatted.
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

enum SinkKind {
    /// Stderr; pretty when it was a TTY at installation time.
    Stderr { pretty: bool },
    /// `--log-out` file, always NDJSON.
    File(std::fs::File),
    /// Test capture, always NDJSON.
    Capture(Arc<Mutex<String>>),
}

fn sink() -> &'static Mutex<SinkKind> {
    static SINK: OnceLock<Mutex<SinkKind>> = OnceLock::new();
    SINK.get_or_init(|| {
        Mutex::new(SinkKind::Stderr { pretty: std::io::stderr().is_terminal() })
    })
}

thread_local! {
    static CORR: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// Set the minimum level that will be emitted.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Current minimum level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        _ => Level::Error,
    }
}

/// Whether a record at `l` would be emitted (one relaxed load).
pub fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Route records to `path` as NDJSON (append mode) — the `--log-out`
/// flag. Replaces the current sink.
pub fn to_file(path: &std::path::Path) -> std::io::Result<()> {
    let f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    *sink().lock().unwrap() = SinkKind::File(f);
    Ok(())
}

/// Route records back to stderr (pretty iff it is a TTY now).
pub fn to_stderr() {
    *sink().lock().unwrap() = SinkKind::Stderr { pretty: std::io::stderr().is_terminal() };
}

/// Swap the sink for an in-memory NDJSON buffer and return it (tests).
/// Call [`to_stderr`] to restore normal output.
pub fn capture() -> Arc<Mutex<String>> {
    let buf = Arc::new(Mutex::new(String::new()));
    *sink().lock().unwrap() = SinkKind::Capture(Arc::clone(&buf));
    buf
}

/// Bind `corr` as this thread's correlation id for the guard's
/// lifetime; nested scopes shadow and restore.
pub struct CorrScope(Option<String>);

impl CorrScope {
    /// Enter a correlation scope. An empty `corr` (a pre-correlation WAL
    /// record, say) binds *no* id rather than an empty one.
    pub fn enter(corr: &str) -> Self {
        let next = if corr.is_empty() { None } else { Some(corr.to_string()) };
        let prev = CORR.with(|c| std::mem::replace(&mut *c.borrow_mut(), next));
        CorrScope(prev)
    }
}

impl Drop for CorrScope {
    fn drop(&mut self) {
        CORR.with(|c| *c.borrow_mut() = self.0.take());
    }
}

/// The correlation id bound to this thread, if any.
pub fn current_corr() -> Option<String> {
    CORR.with(|c| c.borrow().clone())
}

fn epoch_ms() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_millis() as u64).unwrap_or(0)
}

/// Emit one record. `fields` are formatted only when the level passes.
pub fn log(level: Level, target: &str, msg: &str, fields: &[(&str, &dyn std::fmt::Display)]) {
    if !enabled(level) {
        return;
    }
    let ts = epoch_ms();
    let corr = current_corr();
    let mut guard = sink().lock().unwrap();
    let pretty = matches!(&*guard, SinkKind::Stderr { pretty: true });
    let line = if pretty {
        let secs = ts / 1000;
        let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
        let mut out = format!("{h:02}:{m:02}:{s:02} {:5} {target} {msg}", level.label().to_ascii_uppercase());
        for (k, v) in fields {
            out.push_str(&format!(" {k}={v}"));
        }
        if let Some(c) = &corr {
            out.push_str(&format!(" [{c}]"));
        }
        out.push('\n');
        out
    } else {
        let mut o = Obj::new()
            .u64("ts", ts)
            .str("level", level.label())
            .str("target", target)
            .str("msg", msg);
        if let Some(c) = &corr {
            o = o.str("corr", c);
        }
        for (k, v) in fields {
            o = o.str(k, &v.to_string());
        }
        let mut line = o.finish();
        line.push('\n');
        line
    };
    match &mut *guard {
        SinkKind::Stderr { .. } => {
            let _ = std::io::stderr().write_all(line.as_bytes());
        }
        SinkKind::File(f) => {
            let _ = f.write_all(line.as_bytes());
            let _ = f.flush();
        }
        SinkKind::Capture(buf) => buf.lock().unwrap().push_str(&line),
    }
}

/// Emit at `Debug`.
pub fn debug(target: &str, msg: &str, fields: &[(&str, &dyn std::fmt::Display)]) {
    log(Level::Debug, target, msg, fields);
}

/// Emit at `Info`.
pub fn info(target: &str, msg: &str, fields: &[(&str, &dyn std::fmt::Display)]) {
    log(Level::Info, target, msg, fields);
}

/// Emit at `Warn`.
pub fn warn(target: &str, msg: &str, fields: &[(&str, &dyn std::fmt::Display)]) {
    log(Level::Warn, target, msg, fields);
}

/// Emit at `Error`.
pub fn error(target: &str, msg: &str, fields: &[(&str, &dyn std::fmt::Display)]) {
    log(Level::Error, target, msg, fields);
}

#[cfg(test)]
mod tests {
    use super::*;

    // Sink and level are process-global; serialize the tests that swap
    // them.
    static GUARD: Mutex<()> = Mutex::new(());

    #[test]
    fn ndjson_records_carry_fields_and_corr() {
        let _g = GUARD.lock().unwrap();
        let buf = capture();
        set_level(Level::Info);
        {
            let _scope = CorrScope::enter("r-test-1");
            info("unit", "hello", &[("k", &42), ("path", &"/x")]);
        }
        info("unit", "bare", &[]);
        to_stderr();
        let text = buf.lock().unwrap().clone();
        let first = text.lines().next().unwrap();
        let v = crate::util::json::parse(first).unwrap();
        assert_eq!(v.get("level").unwrap().as_str(), Some("info"));
        assert_eq!(v.get("target").unwrap().as_str(), Some("unit"));
        assert_eq!(v.get("msg").unwrap().as_str(), Some("hello"));
        assert_eq!(v.get("corr").unwrap().as_str(), Some("r-test-1"));
        assert_eq!(v.get("k").unwrap().as_str(), Some("42"));
        // Scope closed: the second record has no corr.
        let second = text.lines().nth(1).unwrap();
        let v2 = crate::util::json::parse(second).unwrap();
        assert!(v2.get("corr").is_none());
    }

    #[test]
    fn level_threshold_filters_and_restores() {
        let _g = GUARD.lock().unwrap();
        let buf = capture();
        set_level(Level::Warn);
        info("unit", "suppressed", &[]);
        debug("unit", "suppressed", &[]);
        warn("unit", "kept", &[]);
        error("unit", "kept-too", &[]);
        set_level(Level::Info);
        to_stderr();
        let text = buf.lock().unwrap().clone();
        assert_eq!(text.lines().count(), 2, "{text}");
        assert!(!text.contains("suppressed"));
        assert!(text.contains("kept"));
        assert!(Level::parse("WARN") == Some(Level::Warn));
        assert!(Level::parse("nope").is_none());
    }

    #[test]
    fn corr_scopes_nest_and_restore() {
        let outer = CorrScope::enter("outer");
        assert_eq!(current_corr().as_deref(), Some("outer"));
        {
            let _inner = CorrScope::enter("inner");
            assert_eq!(current_corr().as_deref(), Some("inner"));
        }
        assert_eq!(current_corr().as_deref(), Some("outer"));
        drop(outer);
        assert_eq!(current_corr(), None);
    }
}
