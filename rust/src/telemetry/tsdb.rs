//! `wham::telemetry::tsdb` — bounded in-process metrics history plus
//! the alert engine behind `GET /metrics/history`, `GET /dashboard`,
//! and `GET /alerts/events`.
//!
//! A `GET /metrics` scrape is a point-in-time snapshot; an operator of
//! a month-long `wham serve` needs *trajectories* — is scheduler
//! evals/sec degrading, is the job queue saturating, when did the 5xx
//! burst start. This module keeps that history in fixed memory:
//!
//! * [`Tsdb`] — named series in two downsampling tiers of bounded
//!   rings (default 2 s × 512 fine + 60 s × 1440 coarse ≈ 17 minutes
//!   of fine detail and a day of coarse trend, ~40 bytes/point).
//!   Counters are stored as raw cumulative values and turned into
//!   windowed per-second rates at query time (a counter reset clamps
//!   to zero instead of spiking negative); gauges are stored as-is;
//!   histogram quantiles (p50/p95) are derived per scrape from the
//!   registry's log2 buckets, windowed over the deltas since the
//!   previous scrape.
//! * [`AlertEngine`] — declarative threshold/rate rules
//!   ([`AlertExpr`]) evaluated once per scrape with fire/resolve
//!   hysteresis (N consecutive breaches to fire, M consecutive clears
//!   to resolve). Transitions emit structured-log records under an
//!   `alert-<rule>` correlation scope, bump the
//!   `wham_alerts_{fired,resolved}_total` counters, and append
//!   pre-rendered SSE frames to a bounded ring that
//!   `GET /alerts/events` relays (the jobs tier's chunked-SSE
//!   plumbing).
//! * [`Scraper`] — the background thread sampling the metrics registry
//!   (plus a per-instance [`Collect`] source, e.g. the service state)
//!   into the tsdb and ticking the engine. One final scrape runs at
//!   shutdown so the last window is never lost.
//!
//! Everything here runs *off* the hot paths: the scraper reads the
//! same relaxed atomics `GET /metrics` reads, so search and event-sim
//! loops keep their one-relaxed-load discipline.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::log;
use super::registry::{self, Collect, Counter, Sample};
use crate::util::json::Obj;

/// Alert transitions to the firing state since process start.
static ALERTS_FIRED: Counter = Counter::new(
    "wham_alerts_fired_total",
    "Alert rule transitions to the firing state since process start.",
);

/// Alert transitions back to resolved since process start.
static ALERTS_RESOLVED: Counter = Counter::new(
    "wham_alerts_resolved_total",
    "Alert rule transitions back to resolved since process start.",
);

/// Scrapes the tsdb scraper thread has completed since process start.
static SCRAPES: Counter = Counter::new(
    "wham_tsdb_scrapes_total",
    "Metric-registry scrapes completed by the tsdb scraper thread.",
);

/// Milliseconds since the unix epoch (sample timestamps).
pub fn epoch_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Tier shape of one [`Tsdb`]. Memory is `series × (fine_cap +
/// coarse_cap) × ~40 bytes` regardless of uptime.
#[derive(Debug, Clone)]
pub struct TsdbOptions {
    /// Scrape (and fine-tier) period.
    pub fine_every: Duration,
    /// Fine-tier ring capacity (default 512 × 2 s ≈ 17 min).
    pub fine_cap: usize,
    /// Coarse-tier downsample period.
    pub coarse_every: Duration,
    /// Coarse-tier ring capacity (default 1440 × 60 s = 24 h).
    pub coarse_cap: usize,
}

impl Default for TsdbOptions {
    fn default() -> Self {
        Self {
            fine_every: Duration::from_secs(2),
            fine_cap: 512,
            coarse_every: Duration::from_secs(60),
            coarse_cap: 1440,
        }
    }
}

/// How a stored series is interpreted at query time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeriesKind {
    /// Raw cumulative values; queries emit windowed per-second rates.
    Counter,
    /// Point-in-time values; queries emit them verbatim.
    Gauge,
}

impl SeriesKind {
    /// Wire name used by `/metrics/history` JSON.
    pub fn wire(&self) -> &'static str {
        match self {
            SeriesKind::Counter => "counter_rate",
            SeriesKind::Gauge => "gauge",
        }
    }
}

/// A bounded `(epoch_ms, value)` ring.
struct Ring {
    buf: VecDeque<(u64, f64)>,
    cap: usize,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Self { buf: VecDeque::with_capacity(cap.min(64)), cap: cap.max(1) }
    }

    fn push(&mut self, at_ms: u64, v: f64) {
        if self.buf.len() >= self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back((at_ms, v));
    }
}

struct Series {
    kind: SeriesKind,
    fine: Ring,
    coarse: Ring,
    /// Timestamp of the newest coarse point (downsample gate).
    last_coarse_ms: u64,
}

/// One queried series: name, interpretation, `(epoch_ms, value)` points
/// oldest-first. Counter series carry per-second rates, not raw counts.
#[derive(Debug, Clone)]
pub struct SeriesOut {
    pub name: String,
    pub kind: SeriesKind,
    pub points: Vec<(u64, f64)>,
}

/// Hard cap on distinct series (defense in depth — the metric namespace
/// is code-controlled and far smaller; a bug cannot grow memory).
const MAX_SERIES: usize = 4096;

/// The in-process time-series store. All methods are `&self`; a single
/// mutex guards the series map (scrapes every couple of seconds and
/// queries on the operator path never contend with the mining hot path).
pub struct Tsdb {
    opts: TsdbOptions,
    series: Mutex<BTreeMap<String, Series>>,
    /// Previous cumulative histogram buckets per series key, for
    /// windowed quantiles.
    hist_last: Mutex<HashMap<String, (Vec<(f64, u64)>, u64)>>,
}

/// Render `name{k="v",...}` — the canonical series key, matching the
/// Prometheus exposition's sample-line identity.
fn series_key(name: &str, labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let body: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{name}{{{}}}", body.join(","))
}

/// `*`-wildcard glob match (the only metacharacter `/metrics/history`
/// supports; metric names never contain `*`).
pub fn glob_match(pat: &str, s: &str) -> bool {
    let (pb, sb) = (pat.as_bytes(), s.as_bytes());
    // Iterative backtracking matcher over the single `*` metachar.
    let (mut p, mut i) = (0usize, 0usize);
    let (mut star, mut mark) = (usize::MAX, 0usize);
    while i < sb.len() {
        if p < pb.len() && (pb[p] == sb[i]) {
            p += 1;
            i += 1;
        } else if p < pb.len() && pb[p] == b'*' {
            star = p;
            mark = i;
            p += 1;
        } else if star != usize::MAX {
            p = star + 1;
            mark += 1;
            i = mark;
        } else {
            return false;
        }
    }
    while p < pb.len() && pb[p] == b'*' {
        p += 1;
    }
    p == pb.len()
}

/// Quantile over a *cumulative* `(le, count)` bucket list with `total`
/// observations: the upper bound of the first bucket covering rank
/// `q·total`. Mirrors Prometheus `histogram_quantile` on log2 buckets.
fn bucket_quantile(buckets: &[(f64, u64)], total: u64, q: f64) -> Option<f64> {
    if total == 0 {
        return None;
    }
    let target = (q * total as f64).ceil().max(1.0) as u64;
    for &(le, cum) in buckets {
        if cum >= target {
            return Some(le);
        }
    }
    // Only the +Inf overflow bucket covers the rank: report the largest
    // finite bound we have (or nothing when every bucket is overflow).
    buckets.last().map(|&(le, _)| le)
}

impl Tsdb {
    pub fn new(opts: TsdbOptions) -> Self {
        Self {
            opts,
            series: Mutex::new(BTreeMap::new()),
            hist_last: Mutex::new(HashMap::new()),
        }
    }

    pub fn options(&self) -> &TsdbOptions {
        &self.opts
    }

    /// Store one point, creating the series on first sight. Fine tier
    /// always; coarse tier when `coarse_every` has elapsed since its
    /// newest point.
    fn record(&self, key: String, kind: SeriesKind, at_ms: u64, v: f64) {
        let mut map = self.series.lock().unwrap();
        if !map.contains_key(&key) && map.len() >= MAX_SERIES {
            return;
        }
        let opts = &self.opts;
        let s = map.entry(key).or_insert_with(|| Series {
            kind,
            fine: Ring::new(opts.fine_cap),
            coarse: Ring::new(opts.coarse_cap),
            last_coarse_ms: 0,
        });
        s.fine.push(at_ms, v);
        if at_ms.saturating_sub(s.last_coarse_ms) >= opts.coarse_every.as_millis() as u64 {
            s.coarse.push(at_ms, v);
            s.last_coarse_ms = at_ms;
        }
    }

    /// Ingest one scrape's samples at `at_ms`. Counters store raw
    /// cumulative values; gauges store the value; summaries store each
    /// quantile as a gauge plus the count as a counter; histograms store
    /// windowed p50/p95 gauges (quantile over the bucket deltas since
    /// the previous scrape of the same series) plus the count.
    pub fn ingest(&self, at_ms: u64, samples: &[Sample]) {
        for s in samples {
            match s {
                Sample::Counter { name, labels, value, .. } => {
                    self.record(series_key(name, labels), SeriesKind::Counter, at_ms, *value as f64);
                }
                Sample::Gauge { name, labels, value, .. } => {
                    self.record(series_key(name, labels), SeriesKind::Gauge, at_ms, *value);
                }
                Sample::Summary { name, labels, quantiles, count, .. } => {
                    for &(q, v) in quantiles {
                        let mut ls = labels.clone();
                        ls.push(("quantile".to_string(), format!("{q}")));
                        self.record(series_key(name, &ls), SeriesKind::Gauge, at_ms, v);
                    }
                    self.record(
                        format!("{}_count", series_key(name, labels)),
                        SeriesKind::Counter,
                        at_ms,
                        *count as f64,
                    );
                }
                Sample::Histogram { name, labels, buckets, count, .. } => {
                    let key = series_key(name, labels);
                    // Windowed distribution: per-bucket deltas vs the
                    // previous scrape (first scrape uses the lifetime
                    // distribution). A shrinking count is a reset —
                    // fall back to the current lifetime buckets.
                    let mut last = self.hist_last.lock().unwrap();
                    let (delta, dcount) = match last.get(&key) {
                        Some((prev, pcount)) if count >= pcount => {
                            let prev_at = |le: f64| {
                                prev.iter().find(|&&(l, _)| l >= le).map_or(0, |&(_, c)| c)
                            };
                            let d: Vec<(f64, u64)> = buckets
                                .iter()
                                .map(|&(le, cum)| (le, cum.saturating_sub(prev_at(le))))
                                .collect();
                            (d, count - pcount)
                        }
                        _ => (buckets.clone(), *count),
                    };
                    last.insert(key.clone(), (buckets.clone(), *count));
                    drop(last);
                    if dcount > 0 {
                        for (q, tag) in [(0.5, "0.5"), (0.95, "0.95")] {
                            if let Some(v) = bucket_quantile(&delta, dcount, q) {
                                let mut ls = labels.clone();
                                ls.push(("quantile".to_string(), tag.to_string()));
                                self.record(
                                    series_key(name, &ls),
                                    SeriesKind::Gauge,
                                    at_ms,
                                    v,
                                );
                            }
                        }
                    }
                    self.record(
                        format!("{key}_count"),
                        SeriesKind::Counter,
                        at_ms,
                        *count as f64,
                    );
                }
            }
        }
    }

    /// One full scrape at `at_ms`: every registered counter and
    /// histogram plus the per-instance `extra` sources.
    pub fn scrape(&self, at_ms: u64, extra: &[&dyn Collect]) {
        let mut samples: Vec<Sample> = registry::counters()
            .into_iter()
            .map(|(name, value)| Sample::Counter {
                name: name.to_string(),
                help: String::new(),
                labels: vec![],
                value,
            })
            .collect();
        samples.extend(registry::histogram_samples());
        for src in extra {
            src.collect(&mut samples);
        }
        self.ingest(at_ms, &samples);
        SCRAPES.add(1);
    }

    /// Newest fine sample of one series.
    pub fn latest(&self, series: &str) -> Option<(u64, f64)> {
        let map = self.series.lock().unwrap();
        map.get(series).and_then(|s| s.fine.buf.back().copied())
    }

    /// Per-second rate over the newest fine step of one series (counter
    /// resets clamp to zero). `None` before two samples exist.
    pub fn rate_latest(&self, series: &str) -> Option<f64> {
        let map = self.series.lock().unwrap();
        let s = map.get(series)?;
        let n = s.fine.buf.len();
        if n < 2 {
            return None;
        }
        let (t0, v0) = s.fine.buf[n - 2];
        let (t1, v1) = s.fine.buf[n - 1];
        let dt = (t1.saturating_sub(t0)) as f64 / 1e3;
        if dt <= 0.0 {
            return None;
        }
        Some(((v1 - v0) / dt).max(0.0))
    }

    /// Series matching `pattern` over the trailing `window_secs`,
    /// sorted by name. The fine tier answers windows it still covers;
    /// longer windows fall back to the coarse tier. Counter series are
    /// differentiated into per-second rates (one point per adjacent
    /// sample pair, timestamped at the pair's end, negative deltas —
    /// counter resets — clamped to zero).
    pub fn query(&self, pattern: &str, window_secs: u64, now_ms: u64) -> Vec<SeriesOut> {
        let fine_span_s =
            self.opts.fine_every.as_secs_f64() * self.opts.fine_cap as f64;
        let use_fine = (window_secs as f64) <= fine_span_s;
        let cutoff = now_ms.saturating_sub(window_secs.saturating_mul(1000));
        let map = self.series.lock().unwrap();
        let mut out = Vec::new();
        for (name, s) in map.iter() {
            if !glob_match(pattern, name) {
                continue;
            }
            let ring = if use_fine { &s.fine } else { &s.coarse };
            let raw: Vec<(u64, f64)> =
                ring.buf.iter().copied().filter(|&(t, _)| t >= cutoff).collect();
            let points = match s.kind {
                SeriesKind::Gauge => raw,
                SeriesKind::Counter => raw
                    .windows(2)
                    .filter_map(|w| {
                        let (t0, v0) = w[0];
                        let (t1, v1) = w[1];
                        let dt = t1.saturating_sub(t0) as f64 / 1e3;
                        (dt > 0.0).then(|| (t1, ((v1 - v0) / dt).max(0.0)))
                    })
                    .collect(),
            };
            if !points.is_empty() {
                out.push(SeriesOut { name: name.clone(), kind: s.kind, points });
            }
        }
        out
    }

    /// [`Tsdb::query`] rendered as the `/metrics/history` JSON body.
    pub fn history_json(&self, pattern: &str, window_secs: u64, now_ms: u64) -> String {
        let series = self.query(pattern, window_secs, now_ms);
        let rows: Vec<String> = series
            .iter()
            .map(|s| {
                let pts: Vec<String> = s
                    .points
                    .iter()
                    .map(|&(t, v)| format!("[{t},{}]", crate::util::json::num(v)))
                    .collect();
                Obj::new()
                    .str("name", &s.name)
                    .str("kind", s.kind.wire())
                    .raw("points", &format!("[{}]", pts.join(",")))
                    .finish()
            })
            .collect();
        Obj::new()
            .u64("now_ms", now_ms)
            .u64("window_secs", window_secs)
            .raw("series", &format!("[{}]", rows.join(",")))
            .finish()
    }
}

// ---------------------------------------------------------------------
// Alert engine
// ---------------------------------------------------------------------

/// A declarative alert condition over tsdb series.
#[derive(Debug, Clone)]
pub enum AlertExpr {
    /// Latest value of a gauge series exceeds `threshold`.
    GaugeAbove { series: String, threshold: f64 },
    /// Per-second rate of a series exceeds `per_sec` (applies to
    /// counters and to growing gauges, e.g. WAL bytes on disk).
    RateAbove { series: String, per_sec: f64 },
    /// Per-second rate of `series` falls below `per_sec` while gauge
    /// `gate` is above `gate_above` — e.g. scheduler evals stalling
    /// while a search is in flight.
    RateBelowWhile { series: String, per_sec: f64, gate: String, gate_above: f64 },
}

/// One alert rule: a condition plus fire/resolve hysteresis in scraper
/// ticks.
#[derive(Debug, Clone)]
pub struct AlertRule {
    /// Stable rule id (`job-queue-pressure`), the `rule=` label value.
    pub name: String,
    /// Operator-facing description shown by `/status` and `/dashboard`.
    pub describe: String,
    pub expr: AlertExpr,
    /// Consecutive breaching evaluations before the rule fires.
    pub fire_after: u32,
    /// Consecutive clear evaluations before a firing rule resolves.
    pub resolve_after: u32,
}

/// Point-in-time state of one rule.
#[derive(Debug, Clone)]
pub struct AlertState {
    pub rule: String,
    pub describe: String,
    pub active: bool,
    /// When the current firing episode started (0 while resolved).
    pub since_ms: u64,
    /// The expression's value at the latest evaluation.
    pub value: f64,
}

struct RuleState {
    breaches: u32,
    clears: u32,
    active: bool,
    since_ms: u64,
    value: f64,
}

/// Bounded ring of pre-rendered SSE transition frames; watchers index
/// absolutely and old frames age out, exactly like the jobs tier's
/// per-job frame ring (the stream is never terminal — alerts outlive
/// any one episode).
struct TransitionLog {
    buf: VecDeque<String>,
    base: usize,
}

const TRANSITION_CAP: usize = 256;

/// The alert engine: rules, hysteresis state, and the SSE transition
/// ring. Evaluated by the [`Scraper`] once per scrape.
pub struct AlertEngine {
    rules: Vec<AlertRule>,
    state: Mutex<Vec<RuleState>>,
    frames: Mutex<TransitionLog>,
    cv: Condvar,
}

impl AlertEngine {
    pub fn new(rules: Vec<AlertRule>) -> Self {
        let state = rules
            .iter()
            .map(|_| RuleState { breaches: 0, clears: 0, active: false, since_ms: 0, value: 0.0 })
            .collect();
        Self {
            rules,
            state: Mutex::new(state),
            frames: Mutex::new(TransitionLog { buf: VecDeque::new(), base: 0 }),
            cv: Condvar::new(),
        }
    }

    pub fn rules(&self) -> &[AlertRule] {
        &self.rules
    }

    fn push_frame(&self, frame: String) {
        let mut log = self.frames.lock().unwrap();
        if log.buf.len() >= TRANSITION_CAP {
            log.buf.pop_front();
            log.base += 1;
        }
        log.buf.push_back(frame);
        drop(log);
        self.cv.notify_all();
    }

    /// Transition frames from absolute index `from`; blocks up to
    /// `timeout` when nothing new is buffered. Returns
    /// `(frames, next_from)` — the stream has no terminal state.
    pub fn wait(&self, from: usize, timeout: Duration) -> (Vec<String>, usize) {
        let mut log = self.frames.lock().unwrap();
        if from >= log.base + log.buf.len() {
            let (l, _) = self.cv.wait_timeout(log, timeout).unwrap();
            log = l;
        }
        let start = from.max(log.base);
        let frames: Vec<String> = log.buf.iter().skip(start - log.base).cloned().collect();
        (frames, log.base + log.buf.len())
    }

    /// Absolute index one past the newest buffered frame (new watchers
    /// start here to see only future transitions).
    pub fn frame_head(&self) -> usize {
        let log = self.frames.lock().unwrap();
        log.base + log.buf.len()
    }

    fn transition_json(rule: &AlertRule, active: bool, at_ms: u64, value: f64) -> String {
        Obj::new()
            .str("rule", &rule.name)
            .bool("active", active)
            .u64("at_ms", at_ms)
            .f64("value", value)
            .str("describe", &rule.describe)
            .finish()
    }

    /// Evaluate every rule against `tsdb` once. Call at scrape cadence —
    /// hysteresis counts evaluations, not wall-clock.
    pub fn evaluate(&self, tsdb: &Tsdb, now_ms: u64) {
        let mut st = self.state.lock().unwrap();
        for (rule, rs) in self.rules.iter().zip(st.iter_mut()) {
            let (breach, value) = match &rule.expr {
                AlertExpr::GaugeAbove { series, threshold } => tsdb
                    .latest(series)
                    .map(|(_, v)| (v > *threshold, v))
                    .unwrap_or((false, 0.0)),
                AlertExpr::RateAbove { series, per_sec } => tsdb
                    .rate_latest(series)
                    .map(|r| (r > *per_sec, r))
                    .unwrap_or((false, 0.0)),
                AlertExpr::RateBelowWhile { series, per_sec, gate, gate_above } => {
                    let gated =
                        tsdb.latest(gate).map(|(_, v)| v > *gate_above).unwrap_or(false);
                    match tsdb.rate_latest(series) {
                        Some(r) => (gated && r < *per_sec, r),
                        None => (false, 0.0),
                    }
                }
            };
            rs.value = value;
            if breach {
                rs.breaches += 1;
                rs.clears = 0;
            } else {
                rs.clears += 1;
                rs.breaches = 0;
            }
            if !rs.active && breach && rs.breaches >= rule.fire_after {
                rs.active = true;
                rs.since_ms = now_ms;
                ALERTS_FIRED.add(1);
                let _corr = log::CorrScope::enter(&format!("alert-{}", rule.name));
                log::warn(
                    "alerts",
                    "alert fired",
                    &[("rule", &rule.name), ("value", &value), ("describe", &rule.describe)],
                );
                self.push_frame(crate::jobs::sse_frame(
                    Some("fire"),
                    &Self::transition_json(rule, true, now_ms, value),
                ));
            } else if rs.active && !breach && rs.clears >= rule.resolve_after {
                rs.active = false;
                ALERTS_RESOLVED.add(1);
                let _corr = log::CorrScope::enter(&format!("alert-{}", rule.name));
                log::info(
                    "alerts",
                    "alert resolved",
                    &[("rule", &rule.name), ("value", &value)],
                );
                self.push_frame(crate::jobs::sse_frame(
                    Some("resolve"),
                    &Self::transition_json(rule, false, now_ms, value),
                ));
                rs.since_ms = 0;
            }
        }
    }

    /// Current state of every rule, in declaration order.
    pub fn snapshot(&self) -> Vec<AlertState> {
        let st = self.state.lock().unwrap();
        self.rules
            .iter()
            .zip(st.iter())
            .map(|(r, s)| AlertState {
                rule: r.name.clone(),
                describe: r.describe.clone(),
                active: s.active,
                since_ms: s.since_ms,
                value: s.value,
            })
            .collect()
    }
}

// ---------------------------------------------------------------------
// Scraper thread
// ---------------------------------------------------------------------

struct ScraperShared {
    stop: AtomicBool,
    gate: Mutex<()>,
    cv: Condvar,
}

/// The background scrape loop: every `fine_every` it samples the
/// registry plus the supplied per-instance source into the tsdb and
/// evaluates the alert rules. [`Scraper::stop`] (or drop) runs one
/// final scrape so shutdown never loses the last window.
pub struct Scraper {
    shared: Arc<ScraperShared>,
    join: Option<JoinHandle<()>>,
}

impl Scraper {
    /// Spawn the scraper. `source` appends per-instance samples (the
    /// service state's [`Collect`]) on the scraper thread each tick.
    pub fn start(
        tsdb: Arc<Tsdb>,
        alerts: Arc<AlertEngine>,
        source: Box<dyn Fn(&mut Vec<Sample>) + Send>,
    ) -> Scraper {
        let shared =
            Arc::new(ScraperShared { stop: AtomicBool::new(false), gate: Mutex::new(()), cv: Condvar::new() });
        let shared2 = Arc::clone(&shared);
        let period = tsdb.options().fine_every;
        let join = std::thread::Builder::new()
            .name("wham-tsdb".into())
            .spawn(move || {
                let scrape_once = |t: &Tsdb| {
                    let now = epoch_ms();
                    struct Src<'a>(&'a (dyn Fn(&mut Vec<Sample>) + Send));
                    impl Collect for Src<'_> {
                        fn collect(&self, out: &mut Vec<Sample>) {
                            (self.0)(out)
                        }
                    }
                    let src = Src(&*source);
                    let extra: &[&dyn Collect] = &[&src];
                    t.scrape(now, extra);
                    alerts.evaluate(t, now);
                };
                loop {
                    scrape_once(&tsdb);
                    let guard = shared2.gate.lock().unwrap();
                    let (_g, _timeout) = shared2.cv.wait_timeout(guard, period).unwrap();
                    if shared2.stop.load(Ordering::SeqCst) {
                        break;
                    }
                }
                // Final flush: one last sample so the shutdown window
                // is visible in the history and the trace snapshot.
                scrape_once(&tsdb);
            })
            .expect("spawn tsdb scraper");
        Scraper { shared, join: Some(join) }
    }

    /// Stop the loop, run the final scrape, and join. Idempotent.
    pub fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Scraper {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counter(name: &str, value: u64) -> Sample {
        Sample::Counter {
            name: name.to_string(),
            help: String::new(),
            labels: vec![],
            value,
        }
    }

    fn gauge(name: &str, value: f64) -> Sample {
        Sample::Gauge { name: name.to_string(), help: String::new(), labels: vec![], value }
    }

    fn small_db() -> Tsdb {
        Tsdb::new(TsdbOptions {
            fine_every: Duration::from_secs(2),
            fine_cap: 8,
            coarse_every: Duration::from_secs(60),
            coarse_cap: 4,
            })
    }

    #[test]
    fn rings_evict_oldest_and_stay_bounded() {
        let db = small_db();
        for i in 0..100u64 {
            db.ingest(i * 1000, &[gauge("g", i as f64)]);
        }
        let map = db.series.lock().unwrap();
        let s = map.get("g").unwrap();
        assert_eq!(s.fine.buf.len(), 8, "fine ring must cap at fine_cap");
        assert!(s.coarse.buf.len() <= 4, "coarse ring must cap at coarse_cap");
        // Newest points survive, oldest evicted.
        assert_eq!(s.fine.buf.back().copied(), Some((99_000, 99.0)));
        assert_eq!(s.fine.buf.front().copied(), Some((92_000, 92.0)));
    }

    #[test]
    fn downsample_tiers_agree_where_they_overlap() {
        let db = small_db();
        // 2s ticks for 120 simulated seconds; coarse keeps one per 60s.
        for i in 0..61u64 {
            db.ingest(i * 2000, &[gauge("g", (i * 2) as f64)]);
        }
        let map = db.series.lock().unwrap();
        let s = map.get("g").unwrap();
        // Coarse points are a strict subset of what fine recorded at the
        // same timestamps (value agreement is the tier-consistency bar).
        for &(t, v) in &s.coarse.buf {
            assert_eq!(v, (t / 1000) as f64, "coarse point diverged at t={t}");
        }
        assert!(s.coarse.buf.len() >= 2, "60s boundary must have downsampled");
    }

    #[test]
    fn counter_rates_clamp_resets_to_zero() {
        let db = small_db();
        for (i, v) in [0u64, 10, 20, 5, 15].iter().enumerate() {
            db.ingest(i as u64 * 1000, &[counter("c_total", *v)]);
        }
        let out = db.query("c_total", 60, 5_000);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].kind, SeriesKind::Counter);
        let rates: Vec<f64> = out[0].points.iter().map(|&(_, v)| v).collect();
        // 0→10, 10→20 are 10/s; 20→5 is a reset (clamped); 5→15 is 10/s.
        assert_eq!(rates, vec![10.0, 10.0, 0.0, 10.0]);
    }

    #[test]
    fn glob_matches_star_patterns() {
        assert!(glob_match("*", "anything"));
        assert!(glob_match("wham_*_total", "wham_scheduler_evals_total"));
        assert!(glob_match("wham_http*", "wham_http_requests_total"));
        assert!(!glob_match("wham_http*", "wham_jobs_total"));
        assert!(glob_match("a*b*c", "axxbyyc"));
        assert!(!glob_match("a*b*c", "axxbyy"));
    }

    #[test]
    fn history_json_round_trips_through_the_parser() {
        let db = small_db();
        db.ingest(1000, &[counter("c_total", 0), gauge("g", 1.5)]);
        db.ingest(2000, &[counter("c_total", 4), gauge("g", 2.5)]);
        let v = crate::util::json::parse(&db.history_json("*", 60, 2000)).unwrap();
        let series = v.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 2);
        let c = &series[0];
        assert_eq!(c.get("name").unwrap().as_str(), Some("c_total"));
        assert_eq!(c.get("kind").unwrap().as_str(), Some("counter_rate"));
        let pts = c.get("points").unwrap().as_arr().unwrap();
        let p0 = pts[0].as_arr().unwrap();
        assert_eq!(p0[0].as_u64(), Some(2000));
        assert_eq!(p0[1].as_f64(), Some(4.0));
    }

    #[test]
    fn summary_and_histogram_samples_become_quantile_series() {
        let db = small_db();
        db.ingest(
            1000,
            &[Sample::Summary {
                name: "lat_ms".into(),
                help: String::new(),
                labels: vec![("endpoint".into(), "/x".into())],
                quantiles: vec![(0.5, 3.0), (0.95, 9.0)],
                count: 12,
            }],
        );
        assert_eq!(
            db.latest("lat_ms{endpoint=\"/x\",quantile=\"0.5\"}").map(|(_, v)| v),
            Some(3.0)
        );
        // Histogram: 10 obs ≤ 1, 10 more in (1, 3] → p50 = 1, p95 = 3.
        let h = |buckets: Vec<(f64, u64)>, count| Sample::Histogram {
            name: "dur_s".into(),
            help: String::new(),
            labels: vec![],
            buckets,
            sum: 0.0,
            count,
        };
        db.ingest(2000, &[h(vec![(1.0, 10), (3.0, 20)], 20)]);
        assert_eq!(db.latest("dur_s{quantile=\"0.5\"}").map(|(_, v)| v), Some(1.0));
        assert_eq!(db.latest("dur_s{quantile=\"0.95\"}").map(|(_, v)| v), Some(3.0));
        // Second scrape adds 30 obs, all in (1, 3]: windowed p50 moves
        // to 3 even though the lifetime median is still mixed.
        db.ingest(4000, &[h(vec![(1.0, 10), (3.0, 50)], 50)]);
        assert_eq!(db.latest("dur_s{quantile=\"0.5\"}").map(|(_, v)| v), Some(3.0));
    }

    #[test]
    fn alert_engine_fires_and_resolves_with_hysteresis() {
        let db = small_db();
        let engine = AlertEngine::new(vec![AlertRule {
            name: "queue-pressure".into(),
            describe: "queue near capacity".into(),
            expr: AlertExpr::GaugeAbove { series: "depth".into(), threshold: 5.0 },
            fire_after: 2,
            resolve_after: 2,
        }]);
        let mut t = 0u64;
        let mut step = |engine: &AlertEngine, db: &Tsdb, v: f64| {
            t += 1000;
            db.ingest(t, &[gauge("depth", v)]);
            engine.evaluate(db, t);
            engine.snapshot()[0].active
        };
        assert!(!step(&engine, &db, 9.0), "one breach must not fire yet");
        assert!(step(&engine, &db, 9.0), "second consecutive breach fires");
        assert!(step(&engine, &db, 1.0), "one clear must not resolve yet");
        assert!(!step(&engine, &db, 1.0), "second consecutive clear resolves");
        // A fire and a resolve frame were buffered, in order.
        let (frames, next) = engine.wait(0, Duration::from_millis(10));
        assert_eq!(frames.len(), 2, "{frames:?}");
        assert!(frames[0].starts_with("event: fire\n"), "{}", frames[0]);
        assert!(frames[1].starts_with("event: resolve\n"), "{}", frames[1]);
        assert_eq!(next, 2);
        // An interrupted breach run never fires: 1 breach, clear, 1 breach.
        step(&engine, &db, 9.0);
        step(&engine, &db, 1.0);
        assert!(!step(&engine, &db, 9.0), "hysteresis must require consecutive breaches");
    }

    #[test]
    fn rate_below_while_gates_on_the_gauge() {
        let db = small_db();
        let engine = AlertEngine::new(vec![AlertRule {
            name: "stall".into(),
            describe: "evals stalled during active search".into(),
            expr: AlertExpr::RateBelowWhile {
                series: "evals_total".into(),
                per_sec: 100.0,
                gate: "in_flight".into(),
                gate_above: 0.0,
            },
            fire_after: 1,
            resolve_after: 1,
        }]);
        // Flat counter but nothing in flight: gated off, no fire.
        db.ingest(1000, &[counter("evals_total", 50), gauge("in_flight", 0.0)]);
        db.ingest(2000, &[counter("evals_total", 50), gauge("in_flight", 0.0)]);
        engine.evaluate(&db, 2000);
        assert!(!engine.snapshot()[0].active);
        // Same flat counter with a search in flight: stall fires.
        db.ingest(3000, &[counter("evals_total", 50), gauge("in_flight", 1.0)]);
        engine.evaluate(&db, 3000);
        assert!(engine.snapshot()[0].active);
        // Evals flowing again: resolves.
        db.ingest(4000, &[counter("evals_total", 9050), gauge("in_flight", 1.0)]);
        engine.evaluate(&db, 4000);
        assert!(!engine.snapshot()[0].active);
    }

    #[test]
    fn scraper_samples_the_registry_and_flushes_on_stop() {
        static SCRAPE_TEST: Counter =
            Counter::new("wham_test_tsdb_scraper_total", "tsdb scraper test counter.");
        SCRAPE_TEST.add(3);
        let db = Arc::new(Tsdb::new(TsdbOptions {
            fine_every: Duration::from_millis(20),
            ..TsdbOptions::default()
        }));
        let engine = Arc::new(AlertEngine::new(vec![]));
        let mut scraper = Scraper::start(
            Arc::clone(&db),
            Arc::clone(&engine),
            Box::new(|out| {
                out.push(Sample::Gauge {
                    name: "wham_test_tsdb_source_gauge".into(),
                    help: String::new(),
                    labels: vec![],
                    value: 7.0,
                })
            }),
        );
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while db.latest("wham_test_tsdb_scraper_total").is_none()
            || db.latest("wham_test_tsdb_source_gauge").is_none()
        {
            assert!(std::time::Instant::now() < deadline, "scraper never sampled");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(db.latest("wham_test_tsdb_source_gauge").map(|(_, v)| v), Some(7.0));
        let before = db.latest("wham_test_tsdb_scraper_total").unwrap();
        SCRAPE_TEST.add(2);
        scraper.stop();
        // The final flush observed the post-stop increment.
        let after = db.latest("wham_test_tsdb_scraper_total").unwrap();
        assert!(after.1 >= before.1 + 2.0, "final flush missing: {before:?} -> {after:?}");
    }
}
