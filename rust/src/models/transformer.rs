//! Transformer-family workloads: BERT, GPT2-XL, GPT3, OPT.
//!
//! The forward graphs expose the branching the paper exploits (section
//! 6.3: "the QKV projection in each encoder layer can be executed in
//! parallel across three tensor cores"). Megatron-style tensor model
//! parallelism (section 2.3) is supported by dividing attention heads and
//! MLP width by the TMP degree; the associated all-reduce traffic is
//! modeled analytically by `distributed::network`.

use crate::graph::{GraphBuilder, NodeId, OperatorGraph};

/// Hyper-parameters of a transformer LM (paper Table 4 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransformerCfg {
    pub layers: u64,
    pub hidden: u64,
    pub heads: u64,
    pub seq: u64,
    pub batch: u64,
    pub vocab: u64,
    /// MLP expansion factor (4 for all evaluated models).
    pub ffn_mult: u64,
    /// Tensor-model-parallel degree (1 = no TMP).
    pub tmp: u64,
}

impl TransformerCfg {
    /// Approximate parameter count (for Table 4 cross-checks); input
    /// embedding and LM head are tied, as in the published checkpoints.
    pub fn param_count(&self) -> u64 {
        let per_layer = (4 + 2 * self.ffn_mult) * self.hidden * self.hidden;
        self.layers * per_layer + self.vocab * self.hidden
    }

    /// Bytes all-reduced per device per microbatch in the forward pass
    /// under Megatron TMP (2 all-reduces per layer of B*S*H activations).
    pub fn tmp_allreduce_bytes_fwd(&self) -> u64 {
        if self.tmp <= 1 {
            0
        } else {
            2 * self.layers * self.batch * self.seq * self.hidden * crate::graph::op::DTYPE_BYTES
        }
    }
}

/// BERT-Base: 12 layers, hidden 768 (batch 4, seq 512 — Table 4).
pub fn bert_base() -> TransformerCfg {
    TransformerCfg { layers: 12, hidden: 768, heads: 12, seq: 512, batch: 4, vocab: 30522, ffn_mult: 4, tmp: 1 }
}

/// BERT-Large: 24 layers, hidden 1024 (batch 8, seq 128 — Table 4).
pub fn bert_large() -> TransformerCfg {
    TransformerCfg { layers: 24, hidden: 1024, heads: 16, seq: 128, batch: 8, vocab: 30522, ffn_mult: 4, tmp: 1 }
}

/// GPT2-XL (1.5B): 48 layers, hidden 1600 (batch 32, seq 512 — Table 4).
pub fn gpt2_xl() -> TransformerCfg {
    TransformerCfg { layers: 48, hidden: 1600, heads: 25, seq: 512, batch: 32, vocab: 50257, ffn_mult: 4, tmp: 1 }
}

/// OPT-1.3B: 24 layers, hidden 2048, 32 heads (batch 32 — Table 4).
pub fn opt_1_3b() -> TransformerCfg {
    TransformerCfg { layers: 24, hidden: 2048, heads: 32, seq: 512, batch: 32, vocab: 50272, ffn_mult: 4, tmp: 1 }
}

/// GPT3 (175B): 96 layers, hidden 12288, 96 heads (batch 4, seq 2048).
pub fn gpt3() -> TransformerCfg {
    TransformerCfg { layers: 96, hidden: 12288, heads: 96, seq: 2048, batch: 4, vocab: 50257, ffn_mult: 4, tmp: 1 }
}

/// Emit one transformer block onto `b`, returning its output node.
/// `bs` = batch*seq tokens, `hp` = hidden/tmp partition width.
fn block(b: &mut GraphBuilder, cfg: &TransformerCfg, prev: NodeId, li: u64) -> NodeId {
    let bs = cfg.batch * cfg.seq;
    let h = cfg.hidden;
    let hp = (h / cfg.tmp).max(1);
    let ffn = (cfg.ffn_mult * h / cfg.tmp).max(1);
    let p = |s: &str| format!("l{li}/{s}");

    let ln1 = b.layernorm(p("ln1"), bs, h, &[prev]);
    // QKV: three parallel projections — the branching WHAM exploits.
    let q = b.gemm(p("q"), bs, hp, h, &[ln1]);
    let k = b.gemm(p("k"), bs, hp, h, &[ln1]);
    let v = b.gemm(p("v"), bs, hp, h, &[ln1]);
    // Attention scores + softmax + context (per-device head group).
    let scores = b.gemm_act(p("scores"), bs, cfg.seq, hp, &[q, k]);
    let heads_p = (cfg.heads / cfg.tmp).max(1);
    let sm = b.softmax(p("softmax"), cfg.batch * heads_p * cfg.seq, cfg.seq, &[scores]);
    let ctx = b.gemm_act(p("ctx"), bs, hp, cfg.seq, &[sm, v]);
    let proj = b.gemm(p("proj"), bs, h, hp, &[ctx]);
    let res1 = b.eltwise(p("res1"), bs * h, 1, &[proj, prev]);

    let ln2 = b.layernorm(p("ln2"), bs, h, &[res1]);
    let fc1 = b.gemm(p("fc1"), bs, ffn, h, &[ln2]);
    let gelu = b.eltwise(p("gelu"), bs * ffn, 4, &[fc1]);
    let fc2 = b.gemm(p("fc2"), bs, h, ffn, &[gelu]);
    b.eltwise(p("res2"), bs * h, 1, &[fc2, res1])
}

/// Build the forward graph of a decoder/encoder stack for layers
/// `[lo, hi)` — partial ranges feed the pipeline partitioner. Pass
/// `0..cfg.layers` for the whole model. Embedding is attached when
/// `lo == 0`, the LM head when `hi == cfg.layers`.
pub fn forward_range(cfg: &TransformerCfg, lo: u64, hi: u64) -> OperatorGraph {
    assert!(lo < hi && hi <= cfg.layers);
    let mut b = GraphBuilder::new();
    let bs = cfg.batch * cfg.seq;
    let mut prev = if lo == 0 {
        // Embedding lookup + positional add; owns vocab*hidden params.
        b.fwd(
            "embed",
            crate::graph::OpKind::Elementwise { elems: bs * cfg.hidden, intensity: 2 },
            cfg.vocab * cfg.hidden,
            &[],
        )
    } else {
        // Stage input placeholder (activations arriving from the previous
        // pipeline stage).
        b.eltwise("stage_in", bs * cfg.hidden, 1, &[])
    };
    for li in lo..hi {
        prev = block(&mut b, cfg, prev, li);
    }
    if hi == cfg.layers {
        let lnf = b.layernorm("ln_f", bs, cfg.hidden, &[prev]);
        // LM head (tied embedding: no extra params).
        b.gemm_act("lm_head", bs, cfg.vocab, cfg.hidden, &[lnf]);
    }
    b.finish()
}

/// Whole-model forward graph.
pub fn forward(cfg: &TransformerCfg) -> OperatorGraph {
    forward_range(cfg, 0, cfg.layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;

    #[test]
    fn param_counts_match_model_cards() {
        // Within 15% of the published sizes.
        let close = |got: u64, want: f64| (got as f64 - want).abs() / want < 0.15;
        assert!(close(bert_base().param_count(), 110e6), "{}", bert_base().param_count());
        assert!(close(bert_large().param_count(), 340e6), "{}", bert_large().param_count());
        assert!(close(gpt2_xl().param_count(), 1.5e9), "{}", gpt2_xl().param_count());
        assert!(close(opt_1_3b().param_count(), 1.3e9), "{}", opt_1_3b().param_count());
        assert!(close(gpt3().param_count(), 175e9), "{}", gpt3().param_count());
    }

    #[test]
    fn graph_param_elems_track_cfg() {
        let cfg = bert_base();
        let g = forward(&cfg);
        let got = g.param_elems();
        // The graph's embed op owns the tied vocab*hidden table once.
        let want = cfg.param_count();
        let rel = (got as f64 - want as f64).abs() / want as f64;
        assert!(rel < 0.05, "got {got}, want ~{want}");
    }

    #[test]
    fn forward_graph_is_valid() {
        validate(&forward(&bert_base())).unwrap();
        validate(&forward(&bert_large())).unwrap();
    }

    #[test]
    fn qkv_branches_in_parallel() {
        let g = forward(&bert_base());
        let ln1 = g.ops.iter().position(|o| o.name == "l0/ln1").unwrap();
        assert_eq!(g.succs(ln1).len(), 3, "ln1 fans out to q, k, v");
    }

    #[test]
    fn tmp_divides_per_device_work() {
        let mut cfg = gpt3();
        let full = forward_range(&cfg, 0, 1);
        cfg.tmp = 8;
        let split = forward_range(&cfg, 0, 1);
        let flops = |g: &OperatorGraph| g.total_flops();
        let ratio = flops(&full) / flops(&split);
        // Attention+MLP shrink ~8x; layernorms/residuals don't.
        assert!(ratio > 3.0, "ratio={ratio}");
    }

    #[test]
    fn layer_ranges_compose() {
        let cfg = bert_base();
        let whole = forward(&cfg);
        let a = forward_range(&cfg, 0, 6);
        let z = forward_range(&cfg, 6, 12);
        // Stage op counts cover the whole model (modulo stage_in/lm_head).
        assert!(a.len() + z.len() >= whole.len());
        validate(&a).unwrap();
        validate(&z).unwrap();
    }

    #[test]
    fn tmp_allreduce_traffic() {
        let mut cfg = opt_1_3b();
        assert_eq!(cfg.tmp_allreduce_bytes_fwd(), 0);
        cfg.tmp = 4;
        let expect = 2 * 24 * 32 * 512 * 2048 * 2;
        assert_eq!(cfg.tmp_allreduce_bytes_fwd(), expect);
    }
}
