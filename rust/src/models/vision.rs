//! Vision workloads of Table 4: MobileNet_v3, ResNet-18, Inception_v3,
//! ResNeXt-101 (32x8d), VGG-16.
//!
//! Convolutions are modeled through their implicit GEMM (op.rs); the
//! graphs carry the structural properties that matter to the search:
//! channel/spatial dims per layer, residual and inception branching,
//! squeeze-excite side paths, and depthwise convolutions with tiny
//! reduction dims (the low-utilization layers of paper Figure 2).

use crate::graph::{GraphBuilder, NodeId, OperatorGraph};

/// conv + batchnorm + relu, returning the activation node.
#[allow(clippy::too_many_arguments)]
fn cbr(
    b: &mut GraphBuilder,
    name: &str,
    batch: u64,
    in_c: u64,
    out_c: u64,
    k: u64,
    hw: u64,
    preds: &[NodeId],
) -> NodeId {
    let c = b.conv(format!("{name}/conv"), batch, in_c, out_c, k, k, hw, hw, preds);
    let elems = batch * out_c * hw * hw;
    let bn = b.batchnorm(format!("{name}/bn"), elems, out_c, &[c]);
    b.eltwise(format!("{name}/relu"), elems, 1, &[bn])
}

/// Depthwise conv (+BN+act): per-channel 2-D filter => implicit GEMM with
/// k = kh*kw only, the shape that starves big systolic arrays.
fn dwconv(b: &mut GraphBuilder, name: &str, batch: u64, c: u64, k: u64, hw: u64, preds: &[NodeId]) -> NodeId {
    let conv = b.fwd(
        format!("{name}/dw"),
        crate::graph::OpKind::Conv2d { batch, in_c: 1, out_c: c, kh: k, kw: k, oh: hw, ow: hw },
        c * k * k,
        preds,
    );
    let elems = batch * c * hw * hw;
    let bn = b.batchnorm(format!("{name}/bn"), elems, c, &[conv]);
    b.eltwise(format!("{name}/act"), elems, 3, &[bn])
}

// ------------------------------------------------------------------ VGG-16
/// VGG-16 forward graph (batch 64 per Table 4).
pub fn vgg16(batch: u64) -> OperatorGraph {
    let mut b = GraphBuilder::new();
    // (out_c, convs, spatial) per stage.
    let stages: [(u64, u64, u64); 5] =
        [(64, 2, 224), (128, 2, 112), (256, 3, 56), (512, 3, 28), (512, 3, 14)];
    let mut prev: Option<NodeId> = None;
    let mut in_c = 3;
    for (si, &(out_c, convs, hw)) in stages.iter().enumerate() {
        for ci in 0..convs {
            let preds: Vec<NodeId> = prev.into_iter().collect();
            let n = cbr(&mut b, &format!("s{si}c{ci}"), batch, in_c, out_c, 3, hw, &preds);
            prev = Some(n);
            in_c = out_c;
        }
        let pool = b.reduce(format!("s{si}/pool"), batch * out_c * hw * hw, 1, &[prev.unwrap()]);
        prev = Some(pool);
    }
    let p = prev.unwrap();
    let fc1 = b.gemm("fc1", batch, 4096, 512 * 7 * 7, &[p]);
    let r1 = b.eltwise("fc1/relu", batch * 4096, 1, &[fc1]);
    let fc2 = b.gemm("fc2", batch, 4096, 4096, &[r1]);
    let r2 = b.eltwise("fc2/relu", batch * 4096, 1, &[fc2]);
    let _fc3 = b.gemm("fc3", batch, 1000, 4096, &[r2]);
    b.finish()
}

// --------------------------------------------------------------- ResNet-18
/// Basic residual block: two 3x3 convs + skip connection.
fn basic_block(b: &mut GraphBuilder, name: &str, batch: u64, in_c: u64, out_c: u64, hw: u64, prev: NodeId) -> NodeId {
    let c1 = cbr(b, &format!("{name}/a"), batch, in_c, out_c, 3, hw, &[prev]);
    let c2 = b.conv(format!("{name}/b/conv"), batch, out_c, out_c, 3, 3, hw, hw, &[c1]);
    let bn2 = b.batchnorm(format!("{name}/b/bn"), batch * out_c * hw * hw, out_c, &[c2]);
    // Projection shortcut when the shape changes, identity otherwise.
    let skip = if in_c != out_c {
        b.conv(format!("{name}/proj"), batch, in_c, out_c, 1, 1, hw, hw, &[prev])
    } else {
        prev
    };
    let add = b.eltwise(format!("{name}/add"), batch * out_c * hw * hw, 1, &[bn2, skip]);
    b.eltwise(format!("{name}/relu"), batch * out_c * hw * hw, 1, &[add])
}

/// ResNet-18 forward graph (batch 128 per Table 4).
pub fn resnet18(batch: u64) -> OperatorGraph {
    let mut b = GraphBuilder::new();
    let stem = cbr(&mut b, "stem", batch, 3, 64, 7, 112, &[]);
    let pool = b.reduce("stem/pool", batch * 64 * 112 * 112, 1, &[stem]);
    let mut prev = pool;
    let stages: [(u64, u64); 4] = [(64, 56), (128, 28), (256, 14), (512, 7)];
    let mut in_c = 64;
    for (si, &(out_c, hw)) in stages.iter().enumerate() {
        for bi in 0..2u64 {
            prev = basic_block(&mut b, &format!("s{si}b{bi}"), batch, in_c, out_c, hw, prev);
            in_c = out_c;
        }
    }
    let gap = b.reduce("gap", batch * 512 * 7 * 7, 1, &[prev]);
    let _fc = b.gemm("fc", batch, 1000, 512, &[gap]);
    b.finish()
}

// ------------------------------------------------------------- ResNeXt-101
/// Bottleneck block with cardinality: 1x1 reduce, grouped 3x3 (modeled as
/// `groups_shown` parallel branch convs), 1x1 expand, plus the skip.
#[allow(clippy::too_many_arguments)]
fn resnext_block(
    b: &mut GraphBuilder,
    name: &str,
    batch: u64,
    in_c: u64,
    width: u64,
    out_c: u64,
    hw: u64,
    prev: NodeId,
) -> NodeId {
    const GROUPS_SHOWN: u64 = 4; // 32 cardinality groups, lumped 8-a-piece
    const CARDINALITY: u64 = 32;
    let reduce = cbr(b, &format!("{name}/r"), batch, in_c, width, 1, hw, &[prev]);
    let gw = width / GROUPS_SHOWN;
    let mut branches = Vec::new();
    for gi in 0..GROUPS_SHOWN {
        // Each shown branch lumps 8 true groups; its weight count is that
        // of the grouped conv (cardinality 32), not a dense gw x gw conv.
        let true_params = (CARDINALITY / GROUPS_SHOWN) * (width / CARDINALITY) * (width / CARDINALITY) * 9;
        branches.push(b.fwd(
            format!("{name}/g{gi}"),
            crate::graph::OpKind::Conv2d { batch, in_c: gw, out_c: gw, kh: 3, kw: 3, oh: hw, ow: hw },
            true_params,
            &[reduce],
        ));
    }
    let cat = b.eltwise(format!("{name}/cat"), batch * width * hw * hw, 1, &branches);
    let expand = b.conv(format!("{name}/e"), batch, width, out_c, 1, 1, hw, hw, &[cat]);
    let bn = b.batchnorm(format!("{name}/ebn"), batch * out_c * hw * hw, out_c, &[expand]);
    let skip = if in_c != out_c {
        b.conv(format!("{name}/proj"), batch, in_c, out_c, 1, 1, hw, hw, &[prev])
    } else {
        prev
    };
    b.eltwise(format!("{name}/add"), batch * out_c * hw * hw, 1, &[bn, skip])
}

/// ResNeXt-101 (32x8d) forward graph (batch 16 per Table 4).
pub fn resnext101(batch: u64) -> OperatorGraph {
    let mut b = GraphBuilder::new();
    let stem = cbr(&mut b, "stem", batch, 3, 64, 7, 112, &[]);
    let mut prev = b.reduce("stem/pool", batch * 64 * 112 * 112, 1, &[stem]);
    // (blocks, width, out_c, hw) per stage — 32x8d widths.
    let stages: [(u64, u64, u64, u64); 4] =
        [(3, 256, 256, 56), (4, 512, 512, 28), (23, 1024, 1024, 14), (3, 2048, 2048, 7)];
    let mut in_c = 64;
    for (si, &(blocks, width, out_c, hw)) in stages.iter().enumerate() {
        for bi in 0..blocks {
            prev = resnext_block(&mut b, &format!("s{si}b{bi}"), batch, in_c, width, out_c, hw, prev);
            in_c = out_c;
        }
    }
    let gap = b.reduce("gap", batch * 2048 * 7 * 7, 1, &[prev]);
    let _fc = b.gemm("fc", batch, 1000, 2048, &[gap]);
    b.finish()
}

// ------------------------------------------------------------ Inception_v3
/// Four-branch inception block (1x1 / 5x5 / double-3x3 / pool-proj).
fn inception_a(b: &mut GraphBuilder, name: &str, batch: u64, in_c: u64, hw: u64, prev: NodeId) -> NodeId {
    let b1 = cbr(b, &format!("{name}/b1"), batch, in_c, 64, 1, hw, &[prev]);
    let b2a = cbr(b, &format!("{name}/b2a"), batch, in_c, 48, 1, hw, &[prev]);
    let b2 = cbr(b, &format!("{name}/b2"), batch, 48, 64, 5, hw, &[b2a]);
    let b3a = cbr(b, &format!("{name}/b3a"), batch, in_c, 64, 1, hw, &[prev]);
    let b3b = cbr(b, &format!("{name}/b3b"), batch, 64, 96, 3, hw, &[b3a]);
    let b3 = cbr(b, &format!("{name}/b3"), batch, 96, 96, 3, hw, &[b3b]);
    let pool = b.reduce(format!("{name}/pool"), batch * in_c * hw * hw, 1, &[prev]);
    let b4 = cbr(b, &format!("{name}/b4"), batch, in_c, 64, 1, hw, &[pool]);
    let out_c = 64 + 64 + 96 + 64;
    b.eltwise(format!("{name}/cat"), batch * out_c * hw * hw, 1, &[b1, b2, b3, b4])
}

/// 7x1/1x7 factorized inception block.
fn inception_b(b: &mut GraphBuilder, name: &str, batch: u64, in_c: u64, mid: u64, hw: u64, prev: NodeId) -> NodeId {
    let b1 = cbr(b, &format!("{name}/b1"), batch, in_c, 192, 1, hw, &[prev]);
    let b2a = cbr(b, &format!("{name}/b2a"), batch, in_c, mid, 1, hw, &[prev]);
    // 1x7 then 7x1 — model as k=7 convs with asymmetric cost via kh*kw=7.
    let b2b = b.conv(format!("{name}/b2b"), batch, mid, mid, 1, 7, hw, hw, &[b2a]);
    let b2 = b.conv(format!("{name}/b2c"), batch, mid, 192, 7, 1, hw, hw, &[b2b]);
    let b3a = cbr(b, &format!("{name}/b3a"), batch, in_c, mid, 1, hw, &[prev]);
    let b3b = b.conv(format!("{name}/b3b"), batch, mid, mid, 7, 1, hw, hw, &[b3a]);
    let b3c = b.conv(format!("{name}/b3c"), batch, mid, mid, 1, 7, hw, hw, &[b3b]);
    let b3 = b.conv(format!("{name}/b3d"), batch, mid, 192, 7, 1, hw, hw, &[b3c]);
    let pool = b.reduce(format!("{name}/pool"), batch * in_c * hw * hw, 1, &[prev]);
    let b4 = cbr(b, &format!("{name}/b4"), batch, in_c, 192, 1, hw, &[pool]);
    b.eltwise(format!("{name}/cat"), batch * 768 * hw * hw, 1, &[b1, b2, b3, b4])
}

/// Inception_v3 forward graph (batch 64 per Table 4, 299x299 input).
pub fn inception_v3(batch: u64) -> OperatorGraph {
    let mut b = GraphBuilder::new();
    let s1 = cbr(&mut b, "stem1", batch, 3, 32, 3, 149, &[]);
    let s2 = cbr(&mut b, "stem2", batch, 32, 32, 3, 147, &[s1]);
    let s3 = cbr(&mut b, "stem3", batch, 32, 64, 3, 147, &[s2]);
    let p1 = b.reduce("stem/pool1", batch * 64 * 147 * 147, 1, &[s3]);
    let s4 = cbr(&mut b, "stem4", batch, 64, 80, 1, 73, &[p1]);
    let s5 = cbr(&mut b, "stem5", batch, 80, 192, 3, 71, &[s4]);
    let mut prev = b.reduce("stem/pool2", batch * 192 * 71 * 71, 1, &[s5]);

    // 3x inception-A at 35x35.
    let mut in_c = 192;
    for i in 0..3 {
        prev = inception_a(&mut b, &format!("a{i}"), batch, in_c, 35, prev);
        in_c = 288;
    }
    // Reduction to 17x17.
    let red = cbr(&mut b, "redA", batch, in_c, 384, 3, 17, &[prev]);
    prev = red;
    in_c = 768;
    // 4x inception-B at 17x17 with growing mid widths.
    for (i, mid) in [128u64, 160, 160, 192].iter().enumerate() {
        prev = inception_b(&mut b, &format!("b{i}"), batch, in_c, *mid, 17, prev);
    }
    // Reduction + two C blocks approximated as wide A blocks at 8x8.
    let red2 = cbr(&mut b, "redB", batch, 768, 1280, 3, 8, &[prev]);
    prev = red2;
    prev = inception_a(&mut b, "c0", batch, 1280, 8, prev);
    prev = inception_a(&mut b, "c1", batch, 288, 8, prev);
    let gap = b.reduce("gap", batch * 288 * 8 * 8, 1, &[prev]);
    let _fc = b.gemm("fc", batch, 1000, 2048, &[gap]);
    b.finish()
}

// ------------------------------------------------------------ MobileNet_v3
/// Inverted-residual bneck with optional squeeze-excite.
#[allow(clippy::too_many_arguments)]
fn bneck(
    b: &mut GraphBuilder,
    name: &str,
    batch: u64,
    in_c: u64,
    exp_c: u64,
    out_c: u64,
    k: u64,
    hw: u64,
    se: bool,
    prev: NodeId,
) -> NodeId {
    let expand = cbr(b, &format!("{name}/exp"), batch, in_c, exp_c, 1, hw, &[prev]);
    let dw = dwconv(b, &format!("{name}"), batch, exp_c, k, hw, &[expand]);
    let dw_out = if se {
        // Squeeze-excite: GAP -> fc -> fc -> scale (a side branch).
        let gap = b.reduce(format!("{name}/se/gap"), batch * exp_c * hw * hw, 1, &[dw]);
        let fc1 = b.gemm(format!("{name}/se/fc1"), batch, exp_c / 4, exp_c, &[gap]);
        let fc2 = b.gemm(format!("{name}/se/fc2"), batch, exp_c, exp_c / 4, &[fc1]);
        b.eltwise(format!("{name}/se/scale"), batch * exp_c * hw * hw, 1, &[dw, fc2])
    } else {
        dw
    };
    let proj = b.conv(format!("{name}/proj"), batch, exp_c, out_c, 1, 1, hw, hw, &[dw_out]);
    let bn = b.batchnorm(format!("{name}/pbn"), batch * out_c * hw * hw, out_c, &[proj]);
    if in_c == out_c {
        b.eltwise(format!("{name}/add"), batch * out_c * hw * hw, 1, &[bn, prev])
    } else {
        bn
    }
}

/// MobileNet_v3-Large forward graph (batch 128 per Table 4).
pub fn mobilenet_v3(batch: u64) -> OperatorGraph {
    let mut b = GraphBuilder::new();
    let stem = cbr(&mut b, "stem", batch, 3, 16, 3, 112, &[]);
    // (in, exp, out, k, hw, se) — MobileNetV3-Large table.
    let cfgs: [(u64, u64, u64, u64, u64, bool); 15] = [
        (16, 16, 16, 3, 112, false),
        (16, 64, 24, 3, 56, false),
        (24, 72, 24, 3, 56, false),
        (24, 72, 40, 5, 28, true),
        (40, 120, 40, 5, 28, true),
        (40, 120, 40, 5, 28, true),
        (40, 240, 80, 3, 14, false),
        (80, 200, 80, 3, 14, false),
        (80, 184, 80, 3, 14, false),
        (80, 184, 80, 3, 14, false),
        (80, 480, 112, 3, 14, true),
        (112, 672, 112, 3, 14, true),
        (112, 672, 160, 5, 7, true),
        (160, 960, 160, 5, 7, true),
        (160, 960, 160, 5, 7, true),
    ];
    let mut prev = stem;
    for (i, &(ic, ec, oc, k, hw, se)) in cfgs.iter().enumerate() {
        prev = bneck(&mut b, &format!("bn{i}"), batch, ic, ec, oc, k, hw, se, prev);
    }
    let head = cbr(&mut b, "head", batch, 160, 960, 1, 7, &[prev]);
    let gap = b.reduce("gap", batch * 960 * 7 * 7, 1, &[head]);
    let fc1 = b.gemm("fc1", batch, 1280, 960, &[gap]);
    let hs = b.eltwise("fc1/hswish", batch * 1280, 3, &[fc1]);
    let _fc2 = b.gemm("fc2", batch, 1000, 1280, &[hs]);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;

    #[test]
    fn all_vision_graphs_are_valid() {
        for g in [vgg16(4), resnet18(4), resnext101(2), inception_v3(2), mobilenet_v3(4)] {
            validate(&g).unwrap();
        }
    }

    #[test]
    fn vgg16_param_count_ballpark() {
        let g = vgg16(64);
        let p = g.param_elems() as f64;
        assert!((100e6..160e6).contains(&p), "params={p}");
    }

    #[test]
    fn resnet18_param_count_ballpark() {
        let p = resnet18(128).param_elems() as f64;
        assert!((10e6..35e6).contains(&p), "params={p}");
    }

    #[test]
    fn resnext101_param_count_ballpark() {
        let p = resnext101(16).param_elems() as f64;
        // 32x8d publishes 88.8M; grouped-conv lumping keeps us within 2x.
        assert!((40e6..120e6).contains(&p), "params={p}");
    }

    #[test]
    fn inception_has_branching() {
        let g = inception_v3(2);
        let max_fanout = (0..g.len()).map(|v| g.succs(v).len()).max().unwrap();
        assert!(max_fanout >= 4, "inception blocks fan out 4 ways");
    }

    #[test]
    fn mobilenet_depthwise_has_tiny_k() {
        let g = mobilenet_v3(4);
        let dw = g.ops.iter().find(|o| o.name.ends_with("/dw")).unwrap();
        let r = dw.kind.cost_row();
        assert!(r.k <= 25, "depthwise reduce dim k={}", r.k);
    }

    #[test]
    fn resnet_blocks_have_skip_fanout() {
        let g = resnet18(4);
        // Residual inputs feed both the block and the skip add.
        let fanout2 = (0..g.len()).filter(|&v| g.succs(v).len() >= 2).count();
        assert!(fanout2 >= 2);
    }
}
