//! The workload zoo: all 11 DNNs of paper Table 4 as forward operator
//! graphs, plus the builtin layer of the workload registry. Arbitrary
//! (non-Table-4) workloads come from [`crate::workload`] — declarative
//! JSON specs resolved behind [`crate::api::plan::resolve_workload`].

pub mod gnmt;
pub mod transformer;
pub mod vision;

use std::collections::HashMap;
use std::sync::OnceLock;

use crate::graph::autodiff::{training_graph, Optimizer};
use crate::graph::fusion::fuse;
use crate::graph::OperatorGraph;

/// Registry entry (Table 4 row).
#[derive(Debug, Clone, Copy)]
pub struct ModelInfo {
    pub name: &'static str,
    pub task: &'static str,
    /// Training batch size (Table 4 "Hyper Parameters").
    pub batch: u64,
    /// Accelerator count in the paper's evaluation.
    pub accelerators: u64,
    /// Whether the model is only evaluated under distributed training.
    pub distributed_only: bool,
}

/// All Table 4 workloads.
pub const MODELS: &[ModelInfo] = &[
    ModelInfo { name: "mobilenet_v3", task: "image", batch: 128, accelerators: 1, distributed_only: false },
    ModelInfo { name: "resnet18", task: "image", batch: 128, accelerators: 1, distributed_only: false },
    ModelInfo { name: "inception_v3", task: "image", batch: 64, accelerators: 1, distributed_only: false },
    ModelInfo { name: "resnext101", task: "image", batch: 16, accelerators: 1, distributed_only: false },
    ModelInfo { name: "vgg16", task: "image", batch: 64, accelerators: 1, distributed_only: false },
    ModelInfo { name: "gnmt4", task: "translation", batch: 128, accelerators: 1, distributed_only: false },
    ModelInfo { name: "bert-base", task: "language", batch: 4, accelerators: 1, distributed_only: false },
    ModelInfo { name: "bert-large", task: "language", batch: 8, accelerators: 1, distributed_only: false },
    ModelInfo { name: "opt-1.3b", task: "language", batch: 32, accelerators: 32, distributed_only: true },
    ModelInfo { name: "gpt2-xl", task: "language", batch: 32, accelerators: 32, distributed_only: true },
    ModelInfo { name: "gpt3", task: "language", batch: 4, accelerators: 64, distributed_only: true },
];

/// The 8 single-accelerator workloads (paper section 6.3).
pub fn single_acc_models() -> Vec<&'static str> {
    MODELS.iter().filter(|m| !m.distributed_only).map(|m| m.name).collect()
}

/// The LLMs evaluated under pipeline/TMP training (section 6.4).
pub fn llm_models() -> Vec<&'static str> {
    MODELS.iter().filter(|m| m.distributed_only).map(|m| m.name).collect()
}

/// Name → row index, built once. `info` runs on every request
/// (`api::plan::resolve_workload`), so lookups are map-backed rather
/// than linear scans over [`MODELS`].
fn index() -> &'static HashMap<&'static str, &'static ModelInfo> {
    static INDEX: OnceLock<HashMap<&'static str, &'static ModelInfo>> = OnceLock::new();
    INDEX.get_or_init(|| MODELS.iter().map(|m| (m.name, m)).collect())
}

/// Look up registry info (O(1)).
pub fn info(name: &str) -> Option<&'static ModelInfo> {
    index().get(name).copied()
}

/// Transformer hyper-parameters for LLM workloads (used by the pipeline
/// partitioner and TMP network model).
pub fn transformer_cfg(name: &str) -> Option<transformer::TransformerCfg> {
    match name {
        "bert-base" => Some(transformer::bert_base()),
        "bert-large" => Some(transformer::bert_large()),
        "gpt2-xl" => Some(transformer::gpt2_xl()),
        "opt-1.3b" => Some(transformer::opt_1_3b()),
        "gpt3" => Some(transformer::gpt3()),
        _ => None,
    }
}

/// Build the forward graph for a registered workload.
pub fn forward(name: &str) -> Option<OperatorGraph> {
    let g = match name {
        "mobilenet_v3" => vision::mobilenet_v3(128),
        "resnet18" => vision::resnet18(128),
        "inception_v3" => vision::inception_v3(64),
        "resnext101" => vision::resnext101(16),
        "vgg16" => vision::vgg16(64),
        "gnmt4" => gnmt::forward(&gnmt::gnmt4()),
        _ => transformer::forward(&transformer_cfg(name)?),
    };
    Some(g)
}

/// Full training graph (fused forward + mirrored backward + updates) —
/// the input WHAM's search consumes. Op-fusion is applied first, matching
/// the paper's compiler setup (section 6.2).
pub fn training(name: &str, opt: Optimizer) -> Option<OperatorGraph> {
    let fwd = forward(name)?;
    let (fused, _) = fuse(&fwd);
    Some(training_graph(&fused, opt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;

    #[test]
    fn registry_has_eleven_models() {
        assert_eq!(MODELS.len(), 11);
        assert_eq!(single_acc_models().len(), 8);
        assert_eq!(llm_models().len(), 3);
    }

    #[test]
    fn every_single_acc_training_graph_builds_and_validates() {
        for name in single_acc_models() {
            let g = training(name, Optimizer::Adam)
                .unwrap_or_else(|| panic!("no graph for {name}"));
            validate(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(g.len() > 20, "{name} suspiciously small: {}", g.len());
        }
    }

    #[test]
    fn llm_training_graphs_build() {
        for name in llm_models() {
            let g = training(name, Optimizer::Adam).unwrap();
            validate(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn fusion_reduces_op_count() {
        let fwd = forward("vgg16").unwrap();
        let (fused, n) = crate::graph::fusion::fuse(&fwd);
        assert!(n > 0, "vgg conv+relu pairs should fuse");
        assert!(fused.len() < fwd.len());
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(forward("alexnet").is_none());
        assert!(info("alexnet").is_none());
    }

    #[test]
    fn map_index_agrees_with_linear_scan() {
        for m in MODELS {
            let found = info(m.name).unwrap();
            assert_eq!(found.name, m.name);
            assert_eq!(found.batch, m.batch);
        }
    }
}
