//! GNMT-4 translation workload (Table 4: batch 128, hidden 512).
//!
//! Four-layer LSTM encoder + four-layer decoder with attention. The
//! sequence dimension is chunked (recurrent chains stay sequential inside
//! a layer) so the graph keeps the low intra-layer parallelism that makes
//! RNNs a distinct search workload from transformers and CNNs.

use crate::graph::{GraphBuilder, NodeId, OperatorGraph};

/// GNMT hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct GnmtCfg {
    pub batch: u64,
    pub hidden: u64,
    pub layers: u64,
    pub seq: u64,
    pub vocab: u64,
    /// Sequence chunks per layer (recurrence granularity in the graph).
    pub chunks: u64,
}

/// Table 4 configuration: batch 128, hidden 512, 4 layers.
pub fn gnmt4() -> GnmtCfg {
    GnmtCfg { batch: 128, hidden: 512, layers: 4, seq: 48, vocab: 32_000, chunks: 8 }
}

/// One LSTM layer: sequential chunked gate GEMMs + element-wise gates.
fn lstm_layer(b: &mut GraphBuilder, name: &str, cfg: &GnmtCfg, input: NodeId) -> NodeId {
    let tokens = cfg.batch * cfg.seq / cfg.chunks;
    let mut prev = input;
    for t in 0..cfg.chunks {
        // Gates = [x, h] * W: m = chunk tokens, n = 4H, k = 2H. Weights
        // are owned by the first chunk only (shared across time).
        let params = if t == 0 { 2 * cfg.hidden * 4 * cfg.hidden } else { 0 };
        let g = b.fwd(
            format!("{name}/t{t}/gates"),
            crate::graph::OpKind::Gemm { m: tokens, n: 4 * cfg.hidden, k: 2 * cfg.hidden },
            params,
            &[prev],
        );
        // sigmoid/tanh gate math + cell update.
        prev = b.eltwise(format!("{name}/t{t}/cell"), tokens * cfg.hidden, 6, &[g]);
    }
    prev
}

/// GNMT forward graph: embed -> 4-layer encoder -> attention ->
/// 4-layer decoder -> projection.
pub fn forward(cfg: &GnmtCfg) -> OperatorGraph {
    let mut b = GraphBuilder::new();
    let tokens = cfg.batch * cfg.seq;
    let embed = b.fwd(
        "embed",
        crate::graph::OpKind::Elementwise { elems: tokens * cfg.hidden, intensity: 2 },
        cfg.vocab * cfg.hidden,
        &[],
    );
    let mut enc = embed;
    for l in 0..cfg.layers {
        enc = lstm_layer(&mut b, &format!("enc{l}"), cfg, enc);
    }
    // Decoder embedding (separate vocabulary).
    let dec_embed = b.fwd(
        "dec_embed",
        crate::graph::OpKind::Elementwise { elems: tokens * cfg.hidden, intensity: 2 },
        cfg.vocab * cfg.hidden,
        &[],
    );
    let mut dec = dec_embed;
    for l in 0..cfg.layers {
        dec = lstm_layer(&mut b, &format!("dec{l}"), cfg, dec);
        if l == 0 {
            // Bahdanau-style attention over encoder states after the
            // first decoder layer.
            let scores = b.gemm_act("attn/scores", tokens, cfg.seq, cfg.hidden, &[dec, enc]);
            let sm = b.softmax("attn/softmax", tokens, cfg.seq, &[scores]);
            let ctx = b.gemm_act("attn/ctx", tokens, cfg.hidden, cfg.seq, &[sm, enc]);
            dec = b.eltwise("attn/concat", tokens * 2 * cfg.hidden, 1, &[dec, ctx]);
        }
    }
    let _proj = b.gemm("proj", tokens, cfg.vocab, cfg.hidden, &[dec]);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;

    #[test]
    fn graph_is_valid() {
        validate(&forward(&gnmt4())).unwrap();
    }

    #[test]
    fn param_count_ballpark() {
        // GNMT-4 @ hidden 512: ~ 2 embeddings (32.8M) + 8 LSTM layers
        // (16.8M) + 16.4M projection ~ 66M; Table 4 lists 70M.
        let p = forward(&gnmt4()).param_elems() as f64;
        assert!((50e6..90e6).contains(&p), "params={p}");
    }

    #[test]
    fn recurrence_limits_parallelism() {
        // Within one LSTM layer the chunk GEMMs form a chain.
        let g = forward(&gnmt4());
        let t0 = g.ops.iter().position(|o| o.name == "enc0/t0/gates").unwrap();
        let mut v = t0;
        let mut chain = 1;
        while let Some(&s) = g.succs(v).first() {
            let s = s as usize;
            if !g.ops[s].name.starts_with("enc0/") {
                break;
            }
            v = s;
            chain += 1;
        }
        assert!(chain >= 2 * gnmt4().chunks, "chunks serialize");
    }

    #[test]
    fn encoder_and_decoder_run_in_parallel_at_source() {
        let g = forward(&gnmt4());
        assert!(g.sources().len() >= 2, "embed + dec_embed are independent roots");
    }
}
