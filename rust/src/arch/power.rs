//! Analytical power model: TDP = peak dynamic power + leakage.
//!
//! Dynamic energy constants mirror the cost-model's per-event energies
//! (ref.py); TDP assumes every PE/lane fires each cycle, which is the
//! worst case the thermal solution must sustain — matching how the paper
//! uses Perf/TDP ("correlated with TCO").

use super::{ArchConfig, CLOCK_GHZ};
use crate::cost::native::{E_MAC_PJ, E_VEC_PJ};

/// Leakage per mm^2 of die.
pub const LEAK_W_PER_MM2: f64 = 0.012;
/// HBM interface power floor (controller + PHY at full stream).
pub const HBM_W: f64 = 12.0;

/// Peak dynamic power in watts.
pub fn dynamic_w(c: &ArchConfig) -> f64 {
    let macs = (c.num_tc * c.pes_per_tc()) as f64;
    let lanes = (c.num_vc * c.vc_w) as f64;
    // pJ * GHz = mW; /1e3 -> W.
    (macs * E_MAC_PJ + lanes * E_VEC_PJ) * CLOCK_GHZ / 1e3
}

/// Thermal design power in watts.
pub fn tdp_w(c: &ArchConfig) -> f64 {
    dynamic_w(c) + super::area::area_mm2(c) * LEAK_W_PER_MM2 + HBM_W
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn tpuv2_tdp_ballpark() {
        // TPUv2 chip TDP is ~280W board / ~130W chip; our model should land
        // within the same decade.
        let t = tdp_w(&presets::tpuv2());
        assert!((20.0..300.0).contains(&t), "tdp={t}");
    }

    #[test]
    fn tdp_exceeds_dynamic() {
        let c = presets::nvdla_scaled();
        assert!(tdp_w(&c) > dynamic_w(&c));
    }

    #[test]
    fn power_monotonic_in_pes() {
        assert!(
            dynamic_w(&ArchConfig::new(4, 128, 128, 1, 128))
                > dynamic_w(&ArchConfig::new(1, 128, 128, 1, 128))
        );
    }
}
