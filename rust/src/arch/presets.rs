//! Hand-optimized baseline designs the paper compares against
//! (section 6.2).

use super::ArchConfig;

/// TPUv2-like: 2 computational units, each a 128x128 systolic array plus
/// a 128-wide vector core — `<2, 128x128, 2, 128>`.
pub fn tpuv2() -> ArchConfig {
    ArchConfig::new(2, 128, 128, 2, 128)
}

/// Scaled-up NVDLA-like training design: one 256x256 tensor core and one
/// 256-wide vector core — `<1, 256x256, 1, 256>`.
pub fn nvdla_scaled() -> ArchConfig {
    ArchConfig::new(1, 256, 256, 1, 256)
}

/// TPUv3-like (dual core, two 128x128 arrays each) — used in ablations.
pub fn tpuv3() -> ArchConfig {
    ArchConfig::new(4, 128, 128, 4, 128)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_in_template() {
        assert!(tpuv2().in_template());
        assert!(nvdla_scaled().in_template());
        assert!(tpuv3().in_template());
    }

    #[test]
    fn nvdla_has_one_big_core() {
        let c = nvdla_scaled();
        assert_eq!((c.num_tc, c.pes_per_tc()), (1, 65536));
    }
}
