//! Analytical silicon-area model (the Accelergy/Timeloop-reports
//! substitution — DESIGN.md). Constants are 22 nm-class estimates chosen
//! so a TPUv2-like `<2, 128x128, 2, 128>` lands near its published
//! <330 mm^2 die; absolute values cancel in every paper comparison, which
//! are all ratios against the same model.

use super::ArchConfig;

/// mm^2 per bf16 MAC PE (incl. local pipeline registers).
pub const A_MAC_MM2: f64 = 0.00115;
/// mm^2 per vector lane (wider ALU + register slice).
pub const A_VLANE_MM2: f64 = 0.0035;
/// mm^2 per MiB of SRAM.
pub const A_SRAM_MM2_PER_MIB: f64 = 1.2;
/// Fixed NoC/dispatch overhead per core.
pub const A_NOC_MM2_PER_CORE: f64 = 0.35;
/// Chip-level fixed overhead (HBM PHY, scheduler, semaphore block).
pub const A_FIXED_MM2: f64 = 40.0;

/// Total die area of a design point in mm^2.
pub fn area_mm2(c: &ArchConfig) -> f64 {
    let macs = (c.num_tc * c.pes_per_tc()) as f64;
    let lanes = (c.num_vc * c.vc_w) as f64;
    let sram_mib = c.total_sram_bytes() as f64 / (1024.0 * 1024.0);
    let cores = (c.num_tc + c.num_vc) as f64;
    macs * A_MAC_MM2
        + lanes * A_VLANE_MM2
        + sram_mib * A_SRAM_MM2_PER_MIB
        + cores * A_NOC_MM2_PER_CORE
        + A_FIXED_MM2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn tpuv2_area_ballpark() {
        let a = area_mm2(&presets::tpuv2());
        assert!((60.0..400.0).contains(&a), "area={a}");
    }

    #[test]
    fn area_monotonic_in_cores() {
        let small = ArchConfig::new(1, 128, 128, 1, 128);
        let big = ArchConfig::new(4, 128, 128, 4, 128);
        assert!(area_mm2(&big) > area_mm2(&small));
    }

    #[test]
    fn area_monotonic_in_dim() {
        let small = ArchConfig::new(1, 64, 64, 1, 64);
        let big = ArchConfig::new(1, 256, 256, 1, 64);
        assert!(area_mm2(&big) > area_mm2(&small));
    }
}
