//! Architectural template (paper section 3, Table 2).
//!
//! A design point is `<#TC, TC-Dim, #VC, VC-Width>` plus derived on-chip
//! SRAM sizing; tunables range from 1..=256 cores and 4..=256 per core
//! dimension. [`area`]/[`power`] provide the analytical area/power model
//! (the Accelergy substitution, DESIGN.md) and [`Constraints`] caps the
//! search.

pub mod area;
pub mod power;
pub mod presets;

/// Tunable parameter ranges of the template (paper Table 2).
pub const DIM_MIN: u64 = 4;
pub const DIM_MAX: u64 = 256;
pub const CORES_MIN: u64 = 1;
pub const CORES_MAX: u64 = 256;

/// TPUv2-like clock all designs run at.
pub const CLOCK_GHZ: f64 = 0.94;
/// HBM capacity per accelerator (paper section 6.2 baseline setup).
pub const HBM_BYTES: u64 = 16 * 1024 * 1024 * 1024;
/// HBM bandwidth (paper section 6.2).
pub const HBM_GBPS: f64 = 900.0;
/// Tensor-core L1 register file per core (paper section 6.3: 512 B).
pub const TC_L1_REG_BYTES: u64 = 512;

/// One architecture design point: `<#TC, TC-Dim, #VC, VC-Width>`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ArchConfig {
    pub num_tc: u64,
    pub tc_x: u64,
    pub tc_y: u64,
    pub num_vc: u64,
    pub vc_w: u64,
}

impl ArchConfig {
    /// Construct, asserting template bounds.
    pub fn new(num_tc: u64, tc_x: u64, tc_y: u64, num_vc: u64, vc_w: u64) -> Self {
        let c = Self { num_tc, tc_x, tc_y, num_vc, vc_w };
        debug_assert!(c.in_template(), "config outside template bounds: {c:?}");
        c
    }

    /// Whether all parameters are inside the template ranges (Table 2).
    pub fn in_template(&self) -> bool {
        (CORES_MIN..=CORES_MAX).contains(&self.num_tc)
            && (CORES_MIN..=CORES_MAX).contains(&self.num_vc)
            && (DIM_MIN..=DIM_MAX).contains(&self.tc_x)
            && (DIM_MIN..=DIM_MAX).contains(&self.tc_y)
            && (DIM_MIN..=DIM_MAX).contains(&self.vc_w)
    }

    /// MACs per tensor core.
    pub fn pes_per_tc(&self) -> u64 {
        self.tc_x * self.tc_y
    }

    /// Total MAC count.
    pub fn total_pes(&self) -> u64 {
        self.num_tc * self.pes_per_tc() + self.num_vc * self.vc_w
    }

    /// Peak bf16 TFLOP/s of the design (2 flops/MAC/cycle).
    pub fn peak_tflops(&self) -> f64 {
        2.0 * self.total_pes() as f64 * CLOCK_GHZ / 1e3
    }

    /// L2 SRAM bytes for one tensor core: double-buffered input/weight
    /// tiles plus the output tile (output-stationary dataflow).
    pub fn tc_l2_sram_bytes(&self) -> u64 {
        let tile = self.tc_x * self.tc_y * 4; // fp32 accumulators
        let stream = 2 * (self.tc_x + self.tc_y) * 256 * 2; // double-buffered bf16 streams, k-depth 256
        tile + stream
    }

    /// L2 SRAM bytes for one vector core (sized to keep the lanes fed,
    /// paper section 4.2: "L2-SRAM is set according to VC-Width").
    pub fn vc_l2_sram_bytes(&self) -> u64 {
        2 * self.vc_w * 1024 * 2 // double-buffered 1K-deep bf16 operands
    }

    /// Total on-chip SRAM bytes.
    pub fn total_sram_bytes(&self) -> u64 {
        self.num_tc * (self.tc_l2_sram_bytes() + TC_L1_REG_BYTES)
            + self.num_vc * self.vc_l2_sram_bytes()
    }

    /// Paper-style display: `<#TC, TCx x TCy, #VC, VCw>`.
    pub fn display(&self) -> String {
        format!("<{}, {}x{}, {}, {}>", self.num_tc, self.tc_x, self.tc_y, self.num_vc, self.vc_w)
    }
}

impl std::fmt::Display for ArchConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.display())
    }
}

/// Area / power caps the search must respect (paper: "under a fixed area
/// and power constraint").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Constraints {
    pub max_area_mm2: f64,
    pub max_power_w: f64,
}

impl Default for Constraints {
    /// Defaults sized to the same silicon class as the hand-optimized
    /// baselines: the NVDLA-scaled `<1, 256x256, 1, 256>` corner
    /// (~120 mm^2 / ~48 W in this area model) fits with headroom for a
    /// couple of extra cores, but "max everything" does not — matching
    /// the paper's fixed-area/power search regime (see DESIGN.md).
    fn default() -> Self {
        Self { max_area_mm2: 250.0, max_power_w: 100.0 }
    }
}

impl Constraints {
    /// Whether a config fits within the caps.
    pub fn allows(&self, c: &ArchConfig) -> bool {
        area::area_mm2(c) <= self.max_area_mm2 && power::tdp_w(c) <= self.max_power_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn template_bounds() {
        assert!(ArchConfig { num_tc: 1, tc_x: 4, tc_y: 4, num_vc: 1, vc_w: 4 }.in_template());
        assert!(!ArchConfig { num_tc: 0, tc_x: 4, tc_y: 4, num_vc: 1, vc_w: 4 }.in_template());
        assert!(!ArchConfig { num_tc: 1, tc_x: 512, tc_y: 4, num_vc: 1, vc_w: 4 }.in_template());
    }

    #[test]
    fn tpuv2_peak_flops_ballpark() {
        // <2, 128x128, 2, 128>: 2*16384 MACs + 256 lanes at 0.94 GHz
        // ~ 62 bf16 TFLOP/s — near the marketed 46/chip (we model fused
        // multiply-add on every PE every cycle).
        let c = presets::tpuv2();
        let t = c.peak_tflops();
        assert!((40.0..80.0).contains(&t), "t={t}");
    }

    #[test]
    fn default_constraints_admit_largest_corner() {
        let big = ArchConfig::new(1, 256, 256, 1, 256);
        assert!(Constraints::default().allows(&big));
    }

    #[test]
    fn constraints_reject_max_everything() {
        let monster = ArchConfig::new(256, 256, 256, 256, 256);
        assert!(!Constraints::default().allows(&monster));
    }

    #[test]
    fn display_format() {
        assert_eq!(presets::tpuv2().display(), "<2, 128x128, 2, 128>");
    }
}
