//! Pipeline-schedule simulation: iteration time of GPipe (exact
//! wavefront recurrence) and PipeDream-1F1B (steady-state bound) over
//! heterogeneous per-stage compute times and interconnect transfers.

use super::network::Network;
use super::partition::{split_passes, PartitionedModel};
use super::Scheme;
use crate::arch::{ArchConfig, CLOCK_GHZ};
use crate::cost::annotate::AnnotatedGraph;
use crate::cost::{CostBackend, Dims};
use crate::sched::{asap_alap, greedy_schedule, CoreCount};

/// Per-stage timing on a given accelerator config.
#[derive(Debug, Clone, Copy)]
pub struct StageTimes {
    /// Forward seconds per microbatch (incl. TMP all-reduce share).
    pub fwd_s: f64,
    /// Backward + update seconds per microbatch.
    pub bwd_s: f64,
    /// Energy per microbatch (fwd+bwd), joules.
    pub energy_j: f64,
}

/// Whole-pipeline evaluation.
#[derive(Debug, Clone)]
pub struct PipelineEval {
    pub iter_seconds: f64,
    /// Samples per second at the global batch.
    pub throughput: f64,
    /// Sum of per-device TDP (the Perf/TDP denominator for the system).
    pub total_tdp_w: f64,
    /// throughput / total TDP.
    pub perf_per_tdp: f64,
    /// Index of the slowest stage.
    pub bottleneck: usize,
    /// Per-stage (fwd, bwd) seconds.
    pub stage_times: Vec<StageTimes>,
}

/// Compute-only per-microbatch stage times (no interconnect terms) for
/// one accelerator config, by scheduling the stage's forward and
/// backward subgraphs separately. The cluster layer
/// ([`crate::cluster`]) prices the TMP all-reduce over a routed
/// topology and adds it with [`StageTimes::with_allreduce`];
/// [`stage_times`] is the flat-network composition.
pub fn stage_compute_times(
    stage: &super::partition::Stage,
    config: &ArchConfig,
    backend: &mut dyn CostBackend,
) -> StageTimes {
    let (fg, bg) = split_passes(&stage.graph);
    let cores = CoreCount { tc: config.num_tc, vc: config.num_vc };
    let mut run = |g: &crate::graph::OperatorGraph| -> (f64, f64) {
        if g.is_empty() {
            return (0.0, 0.0);
        }
        let ann = AnnotatedGraph::new(g, Dims::of(config), backend);
        let cp = asap_alap(&ann);
        let sched = greedy_schedule(&ann, &cp, cores);
        (sched.makespan as f64 / (CLOCK_GHZ * 1e9), ann.total_energy_pj() * 1e-12)
    };
    let (fwd_s, fe) = run(&fg);
    let (bwd_s, be) = run(&bg);
    StageTimes { fwd_s, bwd_s, energy_j: fe + be }
}

impl StageTimes {
    /// Add a tensor-model-parallel all-reduce cost to both passes
    /// (Megatron TMP: 2 all-reduces per layer forward, mirrored
    /// backward — `ar_s` is the already-priced per-microbatch total).
    pub fn with_allreduce(mut self, ar_s: f64) -> Self {
        self.fwd_s += ar_s;
        self.bwd_s += ar_s;
        self
    }
}

/// Compute per-microbatch stage times for one accelerator config by
/// scheduling the stage's forward and backward subgraphs separately,
/// with the TMP all-reduce priced on the flat `net`.
pub fn stage_times(
    stage: &super::partition::Stage,
    config: &ArchConfig,
    tmp: u64,
    net: &Network,
    backend: &mut dyn CostBackend,
) -> StageTimes {
    let base = stage_compute_times(stage, config, backend);
    if tmp > 1 {
        base.with_allreduce(net.allreduce_seconds(stage.tmp_allreduce_fwd_bytes, tmp))
    } else {
        base
    }
}

/// Simulate one training iteration of a partitioned model where stage `i`
/// runs on `configs[i]`.
pub fn simulate(
    part: &PartitionedModel,
    configs: &[ArchConfig],
    scheme: Scheme,
    net: &Network,
    backend: &mut dyn CostBackend,
) -> PipelineEval {
    assert_eq!(configs.len(), part.stages.len());
    let times: Vec<StageTimes> = part
        .stages
        .iter()
        .zip(configs)
        .map(|(s, c)| stage_times(s, c, part.tmp, net, backend))
        .collect();
    simulate_with_times(part, configs, &times, scheme, net)
}

/// Simulation core, reusable when stage times are precomputed (the global
/// search evaluates many configs over the same stages).
pub fn simulate_with_times(
    part: &PartitionedModel,
    configs: &[ArchConfig],
    times: &[StageTimes],
    scheme: Scheme,
    net: &Network,
) -> PipelineEval {
    let s = part.stages.len();
    let m = part.num_micro as usize;
    let c: Vec<f64> =
        part.stages.iter().map(|st| net.p2p_seconds(st.boundary_bytes)).collect();

    let iter_seconds = match scheme {
        Scheme::GPipe => {
            // Forward wavefront recurrence over stages x microbatches.
            let mut fwd = vec![vec![0.0f64; m]; s];
            for j in 0..m {
                for i in 0..s {
                    let from_prev_stage = if i == 0 { 0.0 } else { fwd[i - 1][j] + c[i - 1] };
                    let from_prev_micro = if j == 0 { 0.0 } else { fwd[i][j - 1] };
                    fwd[i][j] = from_prev_stage.max(from_prev_micro) + times[i].fwd_s;
                }
            }
            // Flush, then the backward wave runs stages in reverse.
            let flush = fwd[s - 1][m - 1];
            let mut bwd = vec![vec![0.0f64; m]; s];
            for j in 0..m {
                for ii in 0..s {
                    let i = s - 1 - ii; // reverse stage order
                    let from_next_stage = if i == s - 1 { flush } else { bwd[i + 1][j] + c[i] };
                    let from_prev_micro = if j == 0 { 0.0 } else { bwd[i][j - 1] };
                    bwd[i][j] = from_next_stage.max(from_prev_micro) + times[i].bwd_s;
                }
            }
            bwd.iter().map(|row| row[m - 1]).fold(0.0, f64::max)
        }
        Scheme::PipeDream1F1B => {
            // Steady state: the bottleneck stage alternates 1F/1B; fill +
            // drain add one traversal of the pipeline each way.
            let bottleneck =
                times.iter().map(|t| t.fwd_s + t.bwd_s).fold(0.0, f64::max);
            let fill: f64 = times.iter().map(|t| t.fwd_s).sum::<f64>() + c.iter().sum::<f64>();
            let drain: f64 = times.iter().map(|t| t.bwd_s).sum::<f64>() + c.iter().sum::<f64>();
            fill + drain + (m as f64 - 1.0) * bottleneck
        }
    };

    let global_batch = part.micro_batch * part.num_micro;
    let throughput = global_batch as f64 / iter_seconds;
    let total_tdp: f64 = configs
        .iter()
        .map(|cfg| crate::arch::power::tdp_w(cfg) * part.tmp as f64)
        .sum();
    let bottleneck = times
        .iter()
        .enumerate()
        .max_by(|a, b| (a.1.fwd_s + a.1.bwd_s).total_cmp(&(b.1.fwd_s + b.1.bwd_s)))
        .map(|(i, _)| i)
        .unwrap_or(0);
    PipelineEval {
        iter_seconds,
        throughput,
        total_tdp_w: total_tdp,
        perf_per_tdp: throughput / total_tdp,
        bottleneck,
        stage_times: times.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::native::NativeCost;
    use crate::graph::autodiff::Optimizer;
    use crate::models::transformer::gpt2_xl;

    fn small_part() -> PartitionedModel {
        let mut cfg = gpt2_xl();
        cfg.layers = 8; // keep the test fast
        super::super::partition::partition_transformer("mini", &cfg, 4, 1, Optimizer::SgdMomentum)
    }

    #[test]
    fn gpipe_iteration_time_is_sane() {
        let p = small_part();
        let cfgs = vec![presets::tpuv2(); 4];
        let e = simulate(&p, &cfgs, Scheme::GPipe, &Network::default(), &mut NativeCost);
        assert!(e.iter_seconds > 0.0 && e.iter_seconds.is_finite());
        assert!(e.throughput > 0.0);
        // Lower bound: every microbatch crosses the bottleneck stage.
        let bt = &e.stage_times[e.bottleneck];
        let lb = (p.num_micro as f64) * (bt.fwd_s + bt.bwd_s);
        assert!(e.iter_seconds >= lb * 0.99, "{} < {}", e.iter_seconds, lb);
    }

    #[test]
    fn pipelining_beats_serial_execution() {
        let p = small_part();
        let cfgs = vec![presets::tpuv2(); 4];
        let e = simulate(&p, &cfgs, Scheme::GPipe, &Network::default(), &mut NativeCost);
        // Serial: every microbatch through every stage sequentially.
        let serial: f64 = e
            .stage_times
            .iter()
            .map(|t| (t.fwd_s + t.bwd_s) * p.num_micro as f64)
            .sum();
        assert!(e.iter_seconds < serial, "pipeline {} !< serial {serial}", e.iter_seconds);
    }

    #[test]
    fn one_f1b_no_slower_than_gpipe_bound() {
        let p = small_part();
        let cfgs = vec![presets::tpuv2(); 4];
        let g = simulate(&p, &cfgs, Scheme::GPipe, &Network::default(), &mut NativeCost);
        let d = simulate(&p, &cfgs, Scheme::PipeDream1F1B, &Network::default(), &mut NativeCost);
        // Same compute; 1F1B differs in fill/drain shape only.
        let ratio = d.iter_seconds / g.iter_seconds;
        assert!((0.5..1.5).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn faster_configs_raise_throughput() {
        let p = small_part();
        let slow = vec![ArchConfig::new(1, 32, 32, 1, 32); 4];
        let fast = vec![presets::tpuv2(); 4];
        let es = simulate(&p, &slow, Scheme::GPipe, &Network::default(), &mut NativeCost);
        let ef = simulate(&p, &fast, Scheme::GPipe, &Network::default(), &mut NativeCost);
        assert!(ef.throughput > es.throughput);
    }

    #[test]
    fn bottleneck_identifies_slowest_stage() {
        let p = small_part();
        // Give stage 2 a much weaker accelerator.
        let mut cfgs = vec![presets::tpuv2(); 4];
        cfgs[2] = ArchConfig::new(1, 16, 16, 1, 16);
        let e = simulate(&p, &cfgs, Scheme::GPipe, &Network::default(), &mut NativeCost);
        assert_eq!(e.bottleneck, 2);
    }

    #[test]
    fn tdp_scales_with_tmp() {
        let mut cfg = gpt2_xl();
        cfg.layers = 8;
        let p1 = super::super::partition::partition_transformer("a", &cfg, 4, 1, Optimizer::SgdMomentum);
        let p2 = super::super::partition::partition_transformer("a", &cfg, 4, 2, Optimizer::SgdMomentum);
        let cfgs = vec![presets::tpuv2(); 4];
        let e1 = simulate(&p1, &cfgs, Scheme::GPipe, &Network::default(), &mut NativeCost);
        let e2 = simulate(&p2, &cfgs, Scheme::GPipe, &Network::default(), &mut NativeCost);
        assert!((e2.total_tdp_w / e1.total_tdp_w - 2.0).abs() < 1e-9);
    }
}
