//! Interconnect model (paper section 5, "Networking"): homogeneous
//! links between all devices; pipeline parallelism moves boundary
//! activations point-to-point, tensor model parallelism ring-all-reduces
//! partial activations.
//!
//! This flat model is the single-hop special case of the routed
//! [`crate::cluster::topology::Topology`]; the collective costs
//! delegate to the shared model there ([`ring_allreduce_uniform`]), so
//! the flat and hierarchical layers price the same algorithm with the
//! same code. For a hierarchical cluster a ring step can cross several
//! physical hops — latency the flat model undercounts — which is why
//! the cluster simulator routes over a `Topology` instead; convert with
//! [`Network::topology`].

use crate::cluster::topology::{ring_allreduce_uniform, Topology};

/// Interconnect description.
#[derive(Debug, Clone, Copy)]
pub struct Network {
    /// Per-link bandwidth in GB/s (ICI/NVLink-class default).
    pub link_gbps: f64,
    /// Per-message latency in microseconds.
    pub latency_us: f64,
}

impl Default for Network {
    fn default() -> Self {
        Self { link_gbps: 100.0, latency_us: 2.0 }
    }
}

impl Network {
    /// Seconds to move `bytes` point-to-point (stage boundary transfer).
    pub fn p2p_seconds(&self, bytes: u64) -> f64 {
        self.latency_us * 1e-6 + bytes as f64 / (self.link_gbps * 1e9)
    }

    /// Seconds for a ring all-reduce of `bytes` across `n` devices:
    /// 2(n-1) steps, each paying one hop of latency plus a `bytes/n`
    /// chunk — so 2(n-1)/n of the data crosses each link and every step
    /// pays the per-hop latency. Delegates to the shared collective
    /// model in [`crate::cluster::topology`].
    pub fn allreduce_seconds(&self, bytes: u64, n: u64) -> f64 {
        ring_allreduce_uniform(self.latency_us * 1e-6, self.link_gbps, bytes, n)
    }

    /// The compatibility view of this flat network as a single-hop
    /// uniform [`Topology`] over `devices` — collectives over it price
    /// identically to the formulas here.
    pub fn topology(&self, devices: usize) -> Topology {
        Topology::flat(self, devices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_scales_with_bytes() {
        let n = Network::default();
        assert!(n.p2p_seconds(1 << 30) > n.p2p_seconds(1 << 20));
        // 1 GiB over 100 GB/s ~ 10.7 ms.
        let t = n.p2p_seconds(1 << 30);
        assert!((0.009..0.013).contains(&t), "t={t}");
    }

    #[test]
    fn allreduce_single_device_is_free() {
        assert_eq!(Network::default().allreduce_seconds(1 << 20, 1), 0.0);
    }

    #[test]
    fn allreduce_approaches_2x_bandwidth_bound() {
        let n = Network { link_gbps: 100.0, latency_us: 0.0 };
        let bytes = 1u64 << 30;
        let t8 = n.allreduce_seconds(bytes, 8);
        let bound = 2.0 * bytes as f64 / (100.0 * 1e9);
        assert!(t8 < bound && t8 > 0.8 * bound);
    }

    #[test]
    fn allreduce_latency_grows_with_ring() {
        let n = Network { link_gbps: 1e9, latency_us: 5.0 }; // latency-dominated
        assert!(n.allreduce_seconds(8, 16) > n.allreduce_seconds(8, 4));
    }

    // ---- golden costs (satellite: pin the collective model) ----------

    #[test]
    fn golden_default_network_costs() {
        let n = Network::default();
        let mib = 1u64 << 20;
        let close = |a: f64, b: f64| (a - b).abs() <= b * 1e-6;
        // 2 us + 1 MiB / 100 GB/s.
        assert!(close(n.p2p_seconds(mib), 1.248576e-5), "{}", n.p2p_seconds(mib));
        // 2*(8-1) steps of (2 us + (1 MiB / 8) / 100 GB/s):
        // 14 latency hops + 14/8 of the buffer over one link.
        assert!(
            close(n.allreduce_seconds(mib, 8), 4.635008e-5),
            "{}",
            n.allreduce_seconds(mib, 8)
        );
        // 2 devices: 2 steps, each moving half the buffer.
        assert!(close(n.allreduce_seconds(mib, 2), 1.448576e-5));
    }

    #[test]
    fn allreduce_counts_every_per_hop_latency_term() {
        // Latency term must be 2(n-1) hops, not a single constant: with
        // infinite bandwidth the cost is purely the hop count.
        let n = Network { link_gbps: 1e12, latency_us: 3.0 };
        for devs in [2u64, 4, 9, 33] {
            let t = n.allreduce_seconds(1, devs);
            let hops = 2.0 * (devs as f64 - 1.0) * 3.0e-6;
            assert!((t - hops).abs() < 1e-9, "devs={devs}: {t} vs {hops}");
        }
    }

    #[test]
    fn topology_shim_matches_network_formulas() {
        let n = Network { link_gbps: 42.0, latency_us: 7.5 };
        let t = n.topology(6);
        let group: Vec<usize> = (0..6).collect();
        let bytes = 3 << 20;
        assert_eq!(t.ring_allreduce_seconds(&group, bytes), n.allreduce_seconds(bytes, 6));
        assert_eq!(t.p2p_seconds(1, 4, bytes), n.p2p_seconds(bytes));
    }
}
