//! Interconnect model (paper section 5, "Networking"): homogeneous
//! links between all devices; pipeline parallelism moves boundary
//! activations point-to-point, tensor model parallelism ring-all-reduces
//! partial activations.

/// Interconnect description.
#[derive(Debug, Clone, Copy)]
pub struct Network {
    /// Per-link bandwidth in GB/s (ICI/NVLink-class default).
    pub link_gbps: f64,
    /// Per-message latency in microseconds.
    pub latency_us: f64,
}

impl Default for Network {
    fn default() -> Self {
        Self { link_gbps: 100.0, latency_us: 2.0 }
    }
}

impl Network {
    /// Seconds to move `bytes` point-to-point (stage boundary transfer).
    pub fn p2p_seconds(&self, bytes: u64) -> f64 {
        self.latency_us * 1e-6 + bytes as f64 / (self.link_gbps * 1e9)
    }

    /// Seconds for a ring all-reduce of `bytes` across `n` devices:
    /// 2*(n-1)/n of the data crosses each link, plus 2*(n-1) hops of
    /// latency.
    pub fn allreduce_seconds(&self, bytes: u64, n: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let nf = n as f64;
        2.0 * (nf - 1.0) * self.latency_us * 1e-6
            + 2.0 * (nf - 1.0) / nf * bytes as f64 / (self.link_gbps * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_scales_with_bytes() {
        let n = Network::default();
        assert!(n.p2p_seconds(1 << 30) > n.p2p_seconds(1 << 20));
        // 1 GiB over 100 GB/s ~ 10.7 ms.
        let t = n.p2p_seconds(1 << 30);
        assert!((0.009..0.013).contains(&t), "t={t}");
    }

    #[test]
    fn allreduce_single_device_is_free() {
        assert_eq!(Network::default().allreduce_seconds(1 << 20, 1), 0.0);
    }

    #[test]
    fn allreduce_approaches_2x_bandwidth_bound() {
        let n = Network { link_gbps: 100.0, latency_us: 0.0 };
        let bytes = 1u64 << 30;
        let t8 = n.allreduce_seconds(bytes, 8);
        let bound = 2.0 * bytes as f64 / (100.0 * 1e9);
        assert!(t8 < bound && t8 > 0.8 * bound);
    }

    #[test]
    fn allreduce_latency_grows_with_ring() {
        let n = Network { link_gbps: 1e9, latency_us: 5.0 }; // latency-dominated
        assert!(n.allreduce_seconds(8, 16) > n.allreduce_seconds(8, 4));
    }
}
