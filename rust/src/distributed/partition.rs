//! Memory-balanced pipeline partitioner (paper section 5, "Partitioning
//! the model"): splits a model into `stages` contiguous layer groups
//! based on HBM capacity and the memory footprint of training (weights +
//! optimizer state + stashed activations), then expands each partition
//! into its full per-device training graph (backward ops co-located with
//! their forward peers, as all pipeline schemes mandate).

use super::Scheme;
use crate::arch::HBM_BYTES;
use crate::graph::autodiff::{training_graph, Optimizer};
use crate::graph::op::DTYPE_BYTES;
use crate::graph::{OperatorGraph, Pass};
use crate::models::transformer::{forward_range, TransformerCfg};

/// Bytes of optimizer + gradient + master state per parameter (Adam:
/// bf16 weight/grad + fp32 moments).
pub const OPT_STATE_BYTES_PER_PARAM: u64 = 12;

/// One pipeline stage resident on one device.
#[derive(Debug, Clone)]
pub struct Stage {
    /// Stage index (0 = input side).
    pub index: u64,
    /// Layer range `[lo, hi)` hosted by this stage.
    pub layers: (u64, u64),
    /// Full training graph of the partition (microbatch granularity).
    pub graph: OperatorGraph,
    /// Activation bytes crossing to the next stage per microbatch.
    pub boundary_bytes: u64,
    /// Weight + optimizer state bytes.
    pub state_bytes: u64,
    /// Activation stash bytes per in-flight microbatch.
    pub stash_bytes: u64,
    /// All-reduce bytes per microbatch in fwd (Megatron TMP), 0 if tmp=1.
    pub tmp_allreduce_fwd_bytes: u64,
}

/// A partitioned workload ready for pipeline evaluation.
#[derive(Debug, Clone)]
pub struct PartitionedModel {
    pub name: String,
    pub cfg: TransformerCfg,
    pub stages: Vec<Stage>,
    /// Microbatch size each stage graph was built at.
    pub micro_batch: u64,
    /// Microbatches per iteration.
    pub num_micro: u64,
    /// TMP degree (devices per stage).
    pub tmp: u64,
}

impl Stage {
    /// Peak memory footprint under a pipeline scheme.
    pub fn footprint_bytes(&self, scheme: Scheme, num_micro: u64, stages: u64) -> u64 {
        let in_flight = match scheme {
            Scheme::GPipe => num_micro,
            // 1F1B: stage i stashes at most (stages - i) microbatches.
            Scheme::PipeDream1F1B => (stages - self.index).min(num_micro),
        };
        self.state_bytes + self.stash_bytes * in_flight
    }

    /// Whether the stage fits in HBM under the scheme.
    pub fn fits_hbm(&self, scheme: Scheme, num_micro: u64, stages: u64) -> bool {
        self.footprint_bytes(scheme, num_micro, stages) <= HBM_BYTES
    }
}

/// Partition a transformer LM into `stages` pipeline stages with `tmp`-way
/// tensor model parallelism inside each stage (total devices =
/// stages * tmp). Layers are assigned contiguously, balancing the
/// per-stage memory weight (embedding/head layers included).
pub fn partition_transformer(
    name: &str,
    base: &TransformerCfg,
    stages: u64,
    tmp: u64,
    opt: Optimizer,
) -> PartitionedModel {
    assert!(stages >= 1 && tmp >= 1);
    // Layer granularity bounds the pipeline depth (OPT-1.3B has 24
    // layers, so a requested depth of 32 clamps to 24 — the paper splits
    // sub-layer in that case; we keep layer granularity and document the
    // substitution in EXPERIMENTS.md).
    let stages = stages.min(base.layers);
    let micro_batch = (base.batch / stages).max(1);
    let num_micro = (base.batch / micro_batch).max(1);
    let mut cfg = *base;
    cfg.batch = micro_batch;
    cfg.tmp = tmp;

    // Memory weight per layer: per-layer params plus the embedding/LM-head
    // surcharge on the first/last layer.
    let per_layer = (4 + 2 * cfg.ffn_mult) * cfg.hidden * cfg.hidden / tmp;
    let embed = cfg.vocab * cfg.hidden;
    let weight_of = |l: u64| -> u64 {
        let mut w = per_layer;
        if l == 0 {
            w += embed;
        }
        if l == cfg.layers - 1 {
            w += embed / 4; // final layernorm + head working set share
        }
        w
    };
    let total: u64 = (0..cfg.layers).map(weight_of).sum();
    let target = total / stages;

    // Greedy contiguous fill toward the per-stage target, guaranteeing at
    // least one layer per stage and all layers placed.
    let mut bounds = Vec::with_capacity(stages as usize + 1);
    bounds.push(0u64);
    let mut acc = 0u64;
    let mut l = 0u64;
    for s in 0..stages {
        let remaining_stages = stages - s;
        let remaining_layers = cfg.layers - l;
        let mut here = 0u64;
        // Must leave >= 1 layer per remaining stage.
        while l < cfg.layers && remaining_layers - here > remaining_stages - 1 {
            let w = weight_of(l);
            if here > 0 && acc + w > target * (s + 1) {
                break;
            }
            acc += w;
            here += 1;
            l += 1;
        }
        if here == 0 {
            acc += weight_of(l);
            l += 1;
        }
        bounds.push(l);
    }
    *bounds.last_mut().unwrap() = cfg.layers;

    let boundary = micro_batch * cfg.seq * cfg.hidden * DTYPE_BYTES;
    let mut out_stages = Vec::with_capacity(stages as usize);
    for s in 0..stages as usize {
        let (lo, hi) = (bounds[s], bounds[s + 1]);
        let fwd = forward_range(&cfg, lo, hi);
        let graph = training_graph(&fwd, opt);
        let params = graph.param_elems();
        let stash = graph.activation_stash_bytes();
        let ar_bytes = if tmp > 1 {
            2 * (hi - lo) * micro_batch * cfg.seq * cfg.hidden * DTYPE_BYTES
        } else {
            0
        };
        out_stages.push(Stage {
            index: s as u64,
            layers: (lo, hi),
            graph,
            boundary_bytes: boundary,
            state_bytes: params * OPT_STATE_BYTES_PER_PARAM,
            stash_bytes: stash,
            tmp_allreduce_fwd_bytes: ar_bytes,
        });
    }
    PartitionedModel {
        name: name.to_string(),
        cfg,
        stages: out_stages,
        micro_batch,
        num_micro,
        tmp,
    }
}

/// Split a training graph into its forward and backward+update induced
/// subgraphs — the unit the pipeline simulator schedules separately.
pub fn split_passes(g: &OperatorGraph) -> (OperatorGraph, OperatorGraph) {
    let fwd_nodes: Vec<usize> =
        (0..g.len()).filter(|&v| g.ops[v].pass == Pass::Forward).collect();
    let bwd_nodes: Vec<usize> =
        (0..g.len()).filter(|&v| g.ops[v].pass != Pass::Forward).collect();
    (induced(g, &fwd_nodes), induced(g, &bwd_nodes))
}

/// Induced subgraph on `nodes` with transitive edges contracted away
/// (an edge appears when a path in `g` connects two kept nodes through
/// only dropped nodes).
fn induced(g: &OperatorGraph, nodes: &[usize]) -> OperatorGraph {
    let mut keep = vec![usize::MAX; g.len()];
    for (i, &v) in nodes.iter().enumerate() {
        keep[v] = i;
    }
    let mut out = OperatorGraph::default();
    for &v in nodes {
        let mut op = g.ops[v].clone();
        op.fwd_peer = None;
        out.push_op(op, &[]);
    }
    // For each kept node, walk back through dropped preds to find kept
    // ancestors (bounded DFS). `added` dedups per kept node: several
    // dropped paths can reach the same kept ancestor.
    for &v in nodes {
        let nv = keep[v];
        let mut stack: Vec<usize> = g.preds(v).iter().map(|&p| p as usize).collect();
        let mut seen = std::collections::HashSet::new();
        let mut added: Vec<usize> = Vec::new();
        while let Some(p) = stack.pop() {
            if !seen.insert(p) {
                continue;
            }
            if keep[p] != usize::MAX {
                let np = keep[p];
                if !added.contains(&np) {
                    added.push(np);
                    out.add_edge(np, nv);
                }
            } else {
                stack.extend(g.preds(p).iter().map(|&q| q as usize));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;
    use crate::models::transformer::gpt2_xl;

    #[test]
    fn partitions_cover_all_layers_contiguously() {
        let p = partition_transformer("gpt2-xl", &gpt2_xl(), 32, 1, Optimizer::Adam);
        assert_eq!(p.stages.len(), 32);
        assert_eq!(p.stages[0].layers.0, 0);
        assert_eq!(p.stages.last().unwrap().layers.1, 48);
        for w in p.stages.windows(2) {
            assert_eq!(w[0].layers.1, w[1].layers.0);
        }
        for s in &p.stages {
            assert!(s.layers.1 > s.layers.0);
            validate(&s.graph).unwrap();
        }
    }

    #[test]
    fn microbatching_matches_depth() {
        let p = partition_transformer("gpt2-xl", &gpt2_xl(), 32, 1, Optimizer::Adam);
        assert_eq!(p.micro_batch, 1);
        assert_eq!(p.num_micro, 32);
    }

    #[test]
    fn stages_fit_hbm_for_gpt2xl_depth32() {
        let p = partition_transformer("gpt2-xl", &gpt2_xl(), 32, 1, Optimizer::Adam);
        for s in &p.stages {
            assert!(
                s.fits_hbm(Scheme::GPipe, p.num_micro, 32),
                "stage {} footprint {} exceeds HBM",
                s.index,
                s.footprint_bytes(Scheme::GPipe, p.num_micro, 32)
            );
        }
    }

    #[test]
    fn memory_balance_is_reasonable() {
        let p = partition_transformer("gpt2-xl", &gpt2_xl(), 8, 1, Optimizer::Adam);
        let weights: Vec<u64> = p.stages.iter().map(|s| s.state_bytes).collect();
        let max = *weights.iter().max().unwrap() as f64;
        let min = *weights.iter().min().unwrap() as f64;
        // Embedding stage is heavier; everything else within ~3x.
        assert!(max / min < 3.5, "imbalance {max}/{min}");
    }

    #[test]
    fn tmp_shrinks_stage_state() {
        let p1 = partition_transformer("gpt3", &crate::models::transformer::gpt3(), 8, 1, Optimizer::Adam);
        let p8 = partition_transformer("gpt3", &crate::models::transformer::gpt3(), 8, 8, Optimizer::Adam);
        // Compare a middle (embedding-free) stage.
        assert!(p8.stages[4].state_bytes < p1.stages[4].state_bytes / 4);
        assert!(p8.stages[4].tmp_allreduce_fwd_bytes > 0);
        assert_eq!(p1.stages[4].tmp_allreduce_fwd_bytes, 0);
    }

    #[test]
    fn microbatch_accounting_is_exact_across_depths() {
        // micro_batch * num_micro must always reproduce the global
        // batch (up to the minimum-1 clamp), at every pipeline depth.
        let cfg = gpt2_xl(); // batch 32
        for depth in [1u64, 2, 4, 8, 16, 32, 48] {
            let p = partition_transformer("gpt2-xl", &cfg, depth, 1, Optimizer::Adam);
            assert_eq!(
                p.micro_batch * p.num_micro,
                cfg.batch,
                "depth {depth}: {} x {}",
                p.micro_batch,
                p.num_micro
            );
            assert!(p.micro_batch >= 1 && p.num_micro >= 1);
            assert_eq!(p.stages.len() as u64, depth.min(cfg.layers));
        }
        // Depth beyond the batch clamps the microbatch to 1.
        let deep = partition_transformer("gpt2-xl", &cfg, 48, 1, Optimizer::Adam);
        assert_eq!(deep.micro_batch, 1);
        assert_eq!(deep.num_micro, cfg.batch);
    }

    #[test]
    fn stage_op_counts_are_balanced_for_middle_stages() {
        // Stages without the embedding/head surcharge host contiguous
        // identical transformer layers: their graphs must be the same
        // size, and no middle stage may differ by more than one layer's
        // worth of ops.
        let p = partition_transformer("gpt2-xl", &gpt2_xl(), 8, 1, Optimizer::Adam);
        let ops: Vec<usize> = p.stages.iter().map(|s| s.graph.len()).collect();
        let spans: Vec<u64> = p.stages.iter().map(|s| s.layers.1 - s.layers.0).collect();
        let per_layer_ops: Vec<f64> = ops
            .iter()
            .zip(&spans)
            .skip(1)
            .take(p.stages.len() - 2)
            .map(|(&o, &s)| o as f64 / s as f64)
            .collect();
        let max = per_layer_ops.iter().cloned().fold(0.0f64, f64::max);
        let min = per_layer_ops.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 1.05, "middle stages imbalanced: {per_layer_ops:?}");
        // The embedding stage carries the vocab table, so it hosts the
        // fewest layers; the embedding-free stages differ by <= 1.
        let mid_max = *spans[1..].iter().max().unwrap();
        let mid_min = *spans[1..].iter().min().unwrap();
        assert!(mid_max - mid_min <= 1, "spans {spans:?}");
        assert!(spans[0] <= mid_min, "embedding stage must not be the largest: {spans:?}");
    }

    #[test]
    fn boundary_bytes_match_the_activation_shape() {
        let cfg = gpt2_xl();
        let p = partition_transformer("gpt2-xl", &cfg, 8, 1, Optimizer::Adam);
        let expect = p.micro_batch * cfg.seq * cfg.hidden * DTYPE_BYTES;
        for s in &p.stages {
            assert_eq!(s.boundary_bytes, expect);
        }
    }

    #[test]
    fn footprint_grows_with_in_flight_microbatches() {
        let p = partition_transformer("gpt2-xl", &gpt2_xl(), 8, 1, Optimizer::Adam);
        let s0 = &p.stages[0];
        let stages = p.stages.len() as u64;
        let gpipe = s0.footprint_bytes(Scheme::GPipe, p.num_micro, stages);
        let f1b = s0.footprint_bytes(Scheme::PipeDream1F1B, p.num_micro, stages);
        // GPipe stashes every microbatch; 1F1B at most `stages`.
        assert!(gpipe >= f1b);
        assert_eq!(gpipe, s0.state_bytes + s0.stash_bytes * p.num_micro);
        assert_eq!(
            f1b,
            s0.state_bytes + s0.stash_bytes * stages.min(p.num_micro)
        );
    }

    #[test]
    fn split_passes_separates_fwd_bwd() {
        let p = partition_transformer("gpt2-xl", &gpt2_xl(), 32, 1, Optimizer::Adam);
        let g = &p.stages[1].graph;
        let (f, b) = split_passes(g);
        assert_eq!(f.len() + b.len(), g.len());
        assert!(f.ops.iter().all(|o| o.pass == Pass::Forward));
        assert!(b.ops.iter().all(|o| o.pass != Pass::Forward));
        validate(&f).unwrap();
        validate(&b).unwrap();
        // Backward mirrors forward: at least one op per forward tensor op.
        assert!(b.len() >= f.len());
    }
}
