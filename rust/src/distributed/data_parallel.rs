//! Data-parallel composition (paper section 5: "Data parallel is a
//! replicated pipeline and hosts the same graph across" replicas).
//!
//! A DP group of `replicas` pipelines runs the same stages on disjoint
//! batch shards and all-reduces gradients once per iteration. This module
//! composes DP around any pipeline evaluation, completing the
//! DP x PP x TMP space the evaluated systems span.

use super::network::Network;
use super::partition::PartitionedModel;
use super::pipeline::PipelineEval;
use crate::graph::op::DTYPE_BYTES;

/// Evaluation of a data-parallel group of pipelines.
#[derive(Debug, Clone)]
pub struct DataParallelEval {
    /// Replicas in the group.
    pub replicas: u64,
    /// Iteration seconds including the gradient all-reduce.
    pub iter_seconds: f64,
    /// Aggregate samples/second across replicas.
    pub throughput: f64,
    /// Seconds spent in the gradient all-reduce (per iteration).
    pub allreduce_seconds: f64,
    /// Total TDP across all devices of all replicas.
    pub total_tdp_w: f64,
    /// throughput / total TDP.
    pub perf_per_tdp: f64,
}

/// Compose `replicas` copies of an evaluated pipeline. The gradient
/// all-reduce covers every stage's parameters; with the common
/// overlap-with-backward optimization, only the non-overlappable fraction
/// (`exposed`, default 0.3) adds to the critical path.
pub fn data_parallel(
    part: &PartitionedModel,
    pipeline: &PipelineEval,
    replicas: u64,
    net: &Network,
    exposed: f64,
) -> DataParallelEval {
    let full_ar = if replicas > 1 {
        net.allreduce_seconds(gradient_bytes(part), replicas)
    } else {
        0.0
    };
    data_parallel_with_allreduce(part, pipeline, replicas, full_ar, exposed)
}

/// Per-replica gradient bytes the DP all-reduce moves: bounded by the
/// largest stage (stages reduce concurrently on disjoint links).
pub fn gradient_bytes(part: &PartitionedModel) -> u64 {
    part.stages
        .iter()
        .map(|s| s.graph.param_elems() * DTYPE_BYTES)
        .max()
        .unwrap_or(0)
}

/// [`data_parallel`] with the full (un-overlapped) all-reduce cost
/// already priced. This is the flat-path definition of the DP
/// composition; the cluster sweep ([`crate::cluster::strategy`])
/// performs the same composition with the collective routed over a
/// [`crate::cluster::Topology`], sharing [`gradient_bytes`] so the
/// gradient volume cannot drift between the two.
pub fn data_parallel_with_allreduce(
    part: &PartitionedModel,
    pipeline: &PipelineEval,
    replicas: u64,
    full_allreduce_s: f64,
    exposed: f64,
) -> DataParallelEval {
    assert!(replicas >= 1);
    assert!((0.0..=1.0).contains(&exposed));
    let ar = if replicas > 1 { full_allreduce_s * exposed } else { 0.0 };
    let iter = pipeline.iter_seconds + ar;
    let global_batch = part.micro_batch * part.num_micro * replicas;
    let throughput = global_batch as f64 / iter;
    let tdp = pipeline.total_tdp_w * replicas as f64;
    DataParallelEval {
        replicas,
        iter_seconds: iter,
        throughput,
        allreduce_seconds: ar,
        total_tdp_w: tdp,
        perf_per_tdp: throughput / tdp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::native::NativeCost;
    use crate::distributed::partition::partition_transformer;
    use crate::distributed::pipeline::simulate;
    use crate::distributed::Scheme;
    use crate::graph::autodiff::Optimizer;

    fn pipe() -> (PartitionedModel, PipelineEval) {
        let mut cfg = crate::models::transformer::gpt2_xl();
        cfg.layers = 8;
        let p = partition_transformer("mini", &cfg, 4, 1, Optimizer::SgdMomentum);
        let cfgs = vec![presets::tpuv2(); 4];
        let e = simulate(&p, &cfgs, Scheme::GPipe, &Network::default(), &mut NativeCost);
        (p, e)
    }

    #[test]
    fn single_replica_is_identity() {
        let (p, e) = pipe();
        let dp = data_parallel(&p, &e, 1, &Network::default(), 0.3);
        assert_eq!(dp.allreduce_seconds, 0.0);
        assert!((dp.iter_seconds - e.iter_seconds).abs() < 1e-12);
        assert!((dp.throughput - e.throughput).abs() < 1e-9);
    }

    #[test]
    fn replicas_scale_throughput_sublinearly() {
        let (p, e) = pipe();
        let net = Network::default();
        let d1 = data_parallel(&p, &e, 1, &net, 0.3);
        let d4 = data_parallel(&p, &e, 4, &net, 0.3);
        assert!(d4.throughput > d1.throughput, "DP must add throughput");
        assert!(
            d4.throughput < 4.0 * d1.throughput,
            "all-reduce must make scaling sublinear"
        );
        assert!(d4.allreduce_seconds > 0.0);
    }

    #[test]
    fn full_overlap_restores_linear_scaling() {
        let (p, e) = pipe();
        let net = Network::default();
        let d4 = data_parallel(&p, &e, 4, &net, 0.0);
        let d1 = data_parallel(&p, &e, 1, &net, 0.0);
        assert!((d4.throughput / d1.throughput - 4.0).abs() < 1e-9);
    }

    #[test]
    fn tdp_scales_linearly_with_replicas() {
        let (p, e) = pipe();
        let d3 = data_parallel(&p, &e, 3, &Network::default(), 0.3);
        assert!((d3.total_tdp_w / e.total_tdp_w - 3.0).abs() < 1e-9);
    }

    #[test]
    fn scaling_efficiency_curve_is_monotone_in_replicas() {
        // Efficiency = throughput(r) / (r * throughput(1)) must decay
        // monotonically toward the all-reduce-limited floor, staying in
        // (0, 1] throughout.
        let (p, e) = pipe();
        let net = Network::default();
        let t1 = data_parallel(&p, &e, 1, &net, 0.3).throughput;
        let mut last_eff = 1.0 + 1e-12;
        for r in [1u64, 2, 4, 8, 16, 32] {
            let d = data_parallel(&p, &e, r, &net, 0.3);
            let eff = d.throughput / (r as f64 * t1);
            assert!(eff > 0.0 && eff <= 1.0 + 1e-12, "r={r}: eff={eff}");
            assert!(eff <= last_eff + 1e-12, "r={r}: efficiency must not increase");
            last_eff = eff;
        }
    }

    #[test]
    fn faster_interconnect_improves_scaling_efficiency() {
        let (p, e) = pipe();
        let slow = Network { link_gbps: 5.0, latency_us: 10.0 };
        let fast = Network { link_gbps: 500.0, latency_us: 1.0 };
        let ds = data_parallel(&p, &e, 8, &slow, 0.3);
        let df = data_parallel(&p, &e, 8, &fast, 0.3);
        assert!(df.throughput > ds.throughput);
        assert!(df.allreduce_seconds < ds.allreduce_seconds);
    }

    #[test]
    fn exposed_fraction_interpolates_the_allreduce_cost() {
        let (p, e) = pipe();
        let net = Network::default();
        let full = data_parallel(&p, &e, 4, &net, 1.0);
        let half = data_parallel(&p, &e, 4, &net, 0.5);
        let none = data_parallel(&p, &e, 4, &net, 0.0);
        assert!((half.allreduce_seconds - full.allreduce_seconds / 2.0).abs() < 1e-15);
        assert_eq!(none.allreduce_seconds, 0.0);
        assert!(none.throughput > half.throughput && half.throughput > full.throughput);
    }

    #[test]
    fn with_allreduce_variant_matches_flat_composition() {
        // The topology-aware entry point with the flat network's
        // all-reduce cost is exactly the flat composition.
        let (p, e) = pipe();
        let net = Network::default();
        let flat = data_parallel(&p, &e, 4, &net, 0.3);
        let ar = net.allreduce_seconds(gradient_bytes(&p), 4);
        let via = data_parallel_with_allreduce(&p, &e, 4, ar, 0.3);
        assert_eq!(flat.iter_seconds, via.iter_seconds);
        assert_eq!(flat.throughput, via.throughput);
    }

    #[test]
    fn gradient_bytes_tracks_the_largest_stage() {
        let (p, _) = pipe();
        let max_params =
            p.stages.iter().map(|s| s.graph.param_elems()).max().unwrap();
        assert_eq!(gradient_bytes(&p), max_params * crate::graph::op::DTYPE_BYTES);
        assert!(gradient_bytes(&p) > 0);
    }
}
