//! Data-parallel composition (paper section 5: "Data parallel is a
//! replicated pipeline and hosts the same graph across" replicas).
//!
//! A DP group of `replicas` pipelines runs the same stages on disjoint
//! batch shards and all-reduces gradients once per iteration. This module
//! composes DP around any pipeline evaluation, completing the
//! DP x PP x TMP space the evaluated systems span.

use super::network::Network;
use super::partition::PartitionedModel;
use super::pipeline::PipelineEval;
use crate::graph::op::DTYPE_BYTES;

/// Evaluation of a data-parallel group of pipelines.
#[derive(Debug, Clone)]
pub struct DataParallelEval {
    /// Replicas in the group.
    pub replicas: u64,
    /// Iteration seconds including the gradient all-reduce.
    pub iter_seconds: f64,
    /// Aggregate samples/second across replicas.
    pub throughput: f64,
    /// Seconds spent in the gradient all-reduce (per iteration).
    pub allreduce_seconds: f64,
    /// Total TDP across all devices of all replicas.
    pub total_tdp_w: f64,
    /// throughput / total TDP.
    pub perf_per_tdp: f64,
}

/// Compose `replicas` copies of an evaluated pipeline. The gradient
/// all-reduce covers every stage's parameters; with the common
/// overlap-with-backward optimization, only the non-overlappable fraction
/// (`exposed`, default 0.3) adds to the critical path.
pub fn data_parallel(
    part: &PartitionedModel,
    pipeline: &PipelineEval,
    replicas: u64,
    net: &Network,
    exposed: f64,
) -> DataParallelEval {
    assert!(replicas >= 1);
    assert!((0.0..=1.0).contains(&exposed));
    // Per-stage gradient bytes; the per-iteration all-reduce is bounded by
    // the largest stage (stages reduce concurrently on disjoint links).
    let max_grad_bytes = part
        .stages
        .iter()
        .map(|s| s.graph.param_elems() * DTYPE_BYTES)
        .max()
        .unwrap_or(0);
    let ar = if replicas > 1 {
        net.allreduce_seconds(max_grad_bytes, replicas) * exposed
    } else {
        0.0
    };
    let iter = pipeline.iter_seconds + ar;
    let global_batch = part.micro_batch * part.num_micro * replicas;
    let throughput = global_batch as f64 / iter;
    let tdp = pipeline.total_tdp_w * replicas as f64;
    DataParallelEval {
        replicas,
        iter_seconds: iter,
        throughput,
        allreduce_seconds: ar,
        total_tdp_w: tdp,
        perf_per_tdp: throughput / tdp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::native::NativeCost;
    use crate::distributed::partition::partition_transformer;
    use crate::distributed::pipeline::simulate;
    use crate::distributed::Scheme;
    use crate::graph::autodiff::Optimizer;

    fn pipe() -> (PartitionedModel, PipelineEval) {
        let mut cfg = crate::models::transformer::gpt2_xl();
        cfg.layers = 8;
        let p = partition_transformer("mini", &cfg, 4, 1, Optimizer::SgdMomentum);
        let cfgs = vec![presets::tpuv2(); 4];
        let e = simulate(&p, &cfgs, Scheme::GPipe, &Network::default(), &mut NativeCost);
        (p, e)
    }

    #[test]
    fn single_replica_is_identity() {
        let (p, e) = pipe();
        let dp = data_parallel(&p, &e, 1, &Network::default(), 0.3);
        assert_eq!(dp.allreduce_seconds, 0.0);
        assert!((dp.iter_seconds - e.iter_seconds).abs() < 1e-12);
        assert!((dp.throughput - e.throughput).abs() < 1e-9);
    }

    #[test]
    fn replicas_scale_throughput_sublinearly() {
        let (p, e) = pipe();
        let net = Network::default();
        let d1 = data_parallel(&p, &e, 1, &net, 0.3);
        let d4 = data_parallel(&p, &e, 4, &net, 0.3);
        assert!(d4.throughput > d1.throughput, "DP must add throughput");
        assert!(
            d4.throughput < 4.0 * d1.throughput,
            "all-reduce must make scaling sublinear"
        );
        assert!(d4.allreduce_seconds > 0.0);
    }

    #[test]
    fn full_overlap_restores_linear_scaling() {
        let (p, e) = pipe();
        let net = Network::default();
        let d4 = data_parallel(&p, &e, 4, &net, 0.0);
        let d1 = data_parallel(&p, &e, 1, &net, 0.0);
        assert!((d4.throughput / d1.throughput - 4.0).abs() < 1e-9);
    }

    #[test]
    fn tdp_scales_linearly_with_replicas() {
        let (p, e) = pipe();
        let d3 = data_parallel(&p, &e, 3, &Network::default(), 0.3);
        assert!((d3.total_tdp_w / e.total_tdp_w - 3.0).abs() < 1e-9);
    }
}
