//! Global architecture search for distributed training (paper section
//! 5.1): per-stage local searches produce top-k candidate designs; a
//! top-level pruner walks the unique candidates smallest-area-first and
//! selects the architecture(s) optimizing the end-to-end pipeline metric.
//!
//! Three design families are produced (section 6.4):
//! * **WHAM-common** — one config across stages *and* models;
//! * **WHAM-individual** — per model, homogeneous across its pipeline;
//! * **WHAM-mosaic** — per-stage top-1, heterogeneous pipeline.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::network::Network;
use super::partition::PartitionedModel;
use super::pipeline::{simulate_with_times, stage_times, PipelineEval, StageTimes};
use super::Scheme;
use crate::api::progress::{NullSink, Progress, ProgressSink};
use crate::arch::ArchConfig;
use crate::cost::CostBackend;
use crate::metrics::Metric;
use crate::search::engine::{CacheProvider, NoSharedCache, SearchOptions, WhamSearch};

/// Options for the global search.
#[derive(Debug, Clone, Copy)]
pub struct GlobalOptions {
    pub metric: Metric,
    pub scheme: Scheme,
    pub top_k: usize,
    /// Hysteresis levels of the top-level pruner.
    pub hysteresis: u32,
    /// Per-stage local-search options.
    pub local: SearchOptions,
    /// Per-model throughput floor for Perf/TDP (e.g. TPUv2 pipeline).
    pub min_throughput: f64,
    /// Disable the top-level pruner (evaluate the whole k x s x m pool) —
    /// the "unpruned" arm of paper Figure 7.
    pub no_prune: bool,
    /// Worker threads for the independent per-stage local searches
    /// (`1` = serial; `wham global --jobs` and the service default to
    /// the machine's parallelism). The fan-out prefetches results on
    /// per-thread backends behind a mutex-guarded [`CacheProvider`];
    /// merge order and outcomes are identical to the serial walk.
    pub jobs: usize,
}

impl Default for GlobalOptions {
    fn default() -> Self {
        Self {
            metric: Metric::Throughput,
            scheme: Scheme::GPipe,
            top_k: 10,
            hysteresis: 1,
            local: SearchOptions::default(),
            min_throughput: 0.0,
            no_prune: false,
            jobs: 1,
        }
    }
}

/// Result for one model under one config family.
#[derive(Debug, Clone)]
pub struct ModelPipelineResult {
    pub model: String,
    pub configs: Vec<ArchConfig>,
    pub eval: PipelineEval,
}

/// Full global-search outcome.
#[derive(Debug, Clone)]
pub struct GlobalResult {
    /// One config across all stages and models.
    pub common: (ArchConfig, Vec<ModelPipelineResult>),
    /// Per-model homogeneous configs.
    pub individual: Vec<ModelPipelineResult>,
    /// Per-stage heterogeneous (top-1 per stage).
    pub mosaic: Vec<ModelPipelineResult>,
    /// Candidate configs evaluated by the top-level pruner.
    pub candidates_evaluated: usize,
    /// Candidate configs in the unique k x s x m pool.
    pub candidate_pool: usize,
    pub wall: Duration,
    /// Stage-level local searches actually run (after dedup).
    pub local_searches: usize,
    /// True when a [`ProgressSink`] cancelled the search cooperatively;
    /// all three families are still populated, from the candidates
    /// evaluated so far.
    pub cancelled: bool,
}

/// Precomputed per-model stage-time tables, keyed by config.
struct ModelTable<'p> {
    part: &'p PartitionedModel,
    /// stage-signature id per stage (dedup of identical stage graphs).
    sig_of_stage: Vec<usize>,
    /// times[config][sig] -> StageTimes.
    times: HashMap<ArchConfig, Vec<StageTimes>>,
}

impl<'p> ModelTable<'p> {
    fn times_for(
        &mut self,
        cfg: &ArchConfig,
        net: &Network,
        backend: &mut dyn CostBackend,
    ) -> Vec<StageTimes> {
        let sigs = self.sig_of_stage.iter().copied().max().unwrap_or(0) + 1;
        if !self.times.contains_key(cfg) {
            let mut per_sig: Vec<Option<StageTimes>> = vec![None; sigs];
            for (i, s) in self.part.stages.iter().enumerate() {
                let sig = self.sig_of_stage[i];
                if per_sig[sig].is_none() {
                    per_sig[sig] = Some(stage_times(s, cfg, self.part.tmp, net, backend));
                }
            }
            let all: Vec<StageTimes> =
                self.sig_of_stage.iter().map(|&sg| per_sig[sg].unwrap()).collect();
            self.times.insert(*cfg, all);
        }
        self.times[cfg].clone()
    }
}

/// Signature for stage-graph dedup: identical op-count + layer-span +
/// boundary position produces identical graphs for transformer stacks.
/// Shared with the cluster strategy sweep's screening pass.
pub(crate) fn stage_signatures(part: &PartitionedModel) -> Vec<usize> {
    let mut map: HashMap<(usize, u64, bool, bool), usize> = HashMap::new();
    let mut out = Vec::with_capacity(part.stages.len());
    for s in &part.stages {
        let key = (
            s.graph.len(),
            s.layers.1 - s.layers.0,
            s.layers.0 == 0,
            s.layers.1 == part.cfg.layers,
        );
        let next = map.len();
        out.push(*map.entry(key).or_insert(next));
    }
    out
}

/// Run the global search over a set of partitioned models.
pub fn global_search(
    models: &[PartitionedModel],
    opts: &GlobalOptions,
    net: &Network,
    backend: &mut dyn CostBackend,
) -> GlobalResult {
    global_search_cached(models, opts, net, backend, &NoSharedCache)
}

/// [`global_search`] with a shared evaluation cache threaded through the
/// per-stage local searches. A warm design database both skips repeat
/// scheduler runs and warm-starts the top-k candidate pool, which is how
/// the mining service makes repeat `/global` requests cheap.
pub fn global_search_cached(
    models: &[PartitionedModel],
    opts: &GlobalOptions,
    net: &Network,
    backend: &mut dyn CostBackend,
    caches: &dyn CacheProvider,
) -> GlobalResult {
    global_search_observed(models, opts, net, backend, caches, &mut NullSink)
}

/// [`global_search_cached`] reporting progress to `sink` — per-stage
/// local searches stream `"search"` events, the top-level pruner streams
/// `"global"` events — and honoring cooperative cancellation: on a
/// `false` return the remaining pool is skipped and the best designs
/// found so far are assembled (at least one candidate is always
/// evaluated, so the result is well-formed).
pub fn global_search_observed(
    models: &[PartitionedModel],
    opts: &GlobalOptions,
    net: &Network,
    backend: &mut dyn CostBackend,
    caches: &dyn CacheProvider,
    sink: &mut dyn ProgressSink,
) -> GlobalResult {
    assert!(!models.is_empty());
    let t0 = Instant::now();
    let mut cancelled = false;

    // ---- 1. Local search: top-k designs per unique stage ----------------
    // Collect the unique (model, stage-signature) searches first, in the
    // same deterministic order the serial walk used — they are mutually
    // independent, which is what lets `--jobs` fan them out.
    struct LocalTask<'m> {
        model: usize,
        sig: usize,
        graph: &'m crate::graph::OperatorGraph,
        micro_batch: u64,
    }
    let mut sigs_per_model: Vec<Vec<usize>> = Vec::new();
    let mut tasks: Vec<LocalTask> = Vec::new();
    for (mi, part) in models.iter().enumerate() {
        let sigs = stage_signatures(part);
        for (i, stage) in part.stages.iter().enumerate() {
            if sigs[..i].iter().all(|&s| s != sigs[i]) {
                tasks.push(LocalTask {
                    model: mi,
                    sig: sigs[i],
                    graph: &stage.graph,
                    micro_batch: part.micro_batch,
                });
            }
        }
        sigs_per_model.push(sigs);
    }
    let lopts_for = |t: &LocalTask, backend: &mut dyn CostBackend| -> SearchOptions {
        let mut lopts = opts.local;
        lopts.metric = opts.metric;
        lopts.top_k = opts.top_k;
        if let Metric::PerfPerTdp = opts.metric {
            // Per-stage throughput floor: what a TPUv2 achieves on
            // this stage graph — keeps local winners pipeline-viable.
            lopts.min_throughput =
                crate::api::session::tpuv2_floor(t.graph, t.micro_batch, backend);
        }
        lopts
    };

    // Parallel prefetch (tentpole 3): run the local searches concurrently
    // on per-thread backends, handing each worker a cache from the
    // mutex-guarded provider. Progress flows back through a channel and
    // is forwarded to the caller's sink on this thread (sinks are not
    // `Send`); a cancellation from the sink stops the remaining searches
    // cooperatively. The serial merge below consumes the prefetched
    // results in task order, so outcomes match the jobs=1 walk.
    let mut prefetched: Vec<Option<crate::search::engine::SearchResult>> =
        (0..tasks.len()).map(|_| None).collect();
    if opts.jobs > 1 && tasks.len() > 1 {
        if let Ok(choice) = backend.name().parse::<crate::coordinator::BackendChoice>() {
            use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
            // Serializes `cache_for` invocations on the shared provider
            // (the returned caches themselves are used concurrently —
            // `CacheProvider: Sync` and implementors are internally
            // locked).
            let provider_gate = std::sync::Mutex::new(());
            let cancel = AtomicBool::new(false);
            let next = AtomicUsize::new(0);
            let results: Vec<std::sync::Mutex<Option<crate::search::engine::SearchResult>>> =
                (0..tasks.len()).map(|_| std::sync::Mutex::new(None)).collect();
            let (tx, rx) = std::sync::mpsc::channel::<Progress>();
            {
                let tasks = &tasks;
                let results = &results;
                let next = &next;
                let cancel = &cancel;
                let provider_gate = &provider_gate;
                let lopts_for = &lopts_for;
                std::thread::scope(|scope| {
                    for _ in 0..opts.jobs.min(tasks.len()) {
                        let tx = tx.clone();
                        scope.spawn(move || {
                            let Ok(mut wb) = crate::coordinator::make_backend(choice) else {
                                return; // tasks fall back to the serial path
                            };
                            loop {
                                let i = next.fetch_add(1, Ordering::Relaxed);
                                if i >= tasks.len() {
                                    break;
                                }
                                let t = &tasks[i];
                                let lopts = lopts_for(t, wb.as_mut());
                                let mut cache = {
                                    let _gate = provider_gate.lock().unwrap();
                                    caches.cache_for(t.graph, t.micro_batch, &lopts, wb.name())
                                };
                                let mut wsink = |p: &Progress| {
                                    let _ = tx.send(*p);
                                    !cancel.load(Ordering::Relaxed)
                                };
                                let r = WhamSearch::new(t.graph, t.micro_batch, lopts)
                                    .run_with(wb.as_mut(), cache.as_mut(), &mut wsink);
                                *results[i].lock().unwrap() = Some(r);
                            }
                        });
                    }
                    drop(tx);
                    // Forward worker progress on this thread until every
                    // sender is gone (= all workers finished).
                    for p in rx {
                        if !sink.on_progress(&p) {
                            cancel.store(true, Ordering::Relaxed);
                        }
                    }
                });
            }
            cancelled |= cancel.load(Ordering::Relaxed);
            for (slot, m) in prefetched.iter_mut().zip(results) {
                *slot = m.into_inner().unwrap();
            }
        }
    }

    // Serial merge, in task order: identical pool order, mosaic configs,
    // and counters to the serial walk. Tasks the prefetch did not cover
    // (jobs=1, or a worker backend that failed to build) run here.
    let mut local_searches = 0usize;
    let mut pool: Vec<ArchConfig> = Vec::new();
    let mut best_per_sig: Vec<HashMap<usize, ArchConfig>> = vec![HashMap::new(); models.len()];
    for (ti, t) in tasks.iter().enumerate() {
        let r = match prefetched[ti].take() {
            Some(r) => r,
            None => {
                let _span = crate::telemetry::trace::span("global_stage")
                    .arg("model", models[t.model].name.clone())
                    .arg("sig", t.sig);
                let lopts = lopts_for(t, backend);
                let mut cache = caches.cache_for(t.graph, t.micro_batch, &lopts, backend.name());
                WhamSearch::new(t.graph, t.micro_batch, lopts)
                    .run_with(backend, cache.as_mut(), sink)
            }
        };
        cancelled |= r.cancelled;
        local_searches += 1;
        for p in r.top.points() {
            if !pool.contains(&p.config) {
                pool.push(p.config);
            }
        }
        best_per_sig[t.model].insert(t.sig, r.best.config);
    }
    // Per model: best local design per stage (for Mosaic).
    let mut mosaic_cfgs: Vec<Vec<ArchConfig>> = Vec::new();
    let mut tables: Vec<ModelTable> = Vec::new();
    for (mi, part) in models.iter().enumerate() {
        let sigs = &sigs_per_model[mi];
        mosaic_cfgs
            .push((0..part.stages.len()).map(|i| best_per_sig[mi][&sigs[i]]).collect());
        tables.push(ModelTable { part, sig_of_stage: sigs.to_vec(), times: HashMap::new() });
    }
    let candidate_pool = pool.len();

    // ---- 2. Top-level pruner over the unique pool, smallest area first --
    pool.sort_by(|a, b| {
        crate::arch::area::area_mm2(a).total_cmp(&crate::arch::area::area_mm2(b))
    });
    let score_pipeline = |e: &PipelineEval, opts: &GlobalOptions| -> f64 {
        match opts.metric {
            Metric::Throughput => e.throughput,
            Metric::PerfPerTdp => {
                if e.throughput + 1e-12 < opts.min_throughput {
                    -1.0 + e.throughput / opts.min_throughput.max(1e-12) * 1e-3
                } else {
                    e.perf_per_tdp
                }
            }
        }
    };

    // Evaluate a homogeneous config on every model; returns per-model
    // scores and evals.
    let evaluate_cfg = |cfg: &ArchConfig,
                            tables: &mut [ModelTable],
                            backend: &mut dyn CostBackend|
     -> Vec<(f64, PipelineEval)> {
        tables
            .iter_mut()
            .map(|t| {
                let times = t.times_for(cfg, net, backend);
                let cfgs = vec![*cfg; t.part.stages.len()];
                let e = simulate_with_times(t.part, &cfgs, &times, opts.scheme, net);
                (score_pipeline(&e, opts), e)
            })
            .collect()
    };

    // Group the pool into area *levels* (paper Figure-6-style tree: each
    // level holds designs of the same/similar area; root = smallest).
    let mut levels: Vec<Vec<ArchConfig>> = Vec::new();
    for cfg in &pool {
        let a = crate::arch::area::area_mm2(cfg);
        match levels.last() {
            Some(l) if a <= crate::arch::area::area_mm2(&l[0]) * 1.15 => {
                levels.last_mut().unwrap().push(*cfg)
            }
            _ => levels.push(vec![*cfg]),
        }
    }

    let mut evaluated = 0usize;
    let mut best_common: Option<(f64, ArchConfig, Vec<(f64, PipelineEval)>)> = None;
    let mut best_individual: Vec<Option<(f64, ArchConfig, PipelineEval)>> =
        vec![None; models.len()];
    let mut worse_levels = 0u32;
    // Top-level pruning (section 5.1): stop when `hysteresis`+1
    // consecutive whole area-levels improve no model.
    'levels: for level in &levels {
        let _span = crate::telemetry::trace::span("global_prune").arg("level", level.len());
        let mut improved_level = false;
        for cfg in level {
            let results = evaluate_cfg(cfg, &mut tables, backend);
            evaluated += 1;
            let mean: f64 = results.iter().map(|(s, _)| s).sum::<f64>() / results.len() as f64;
            for (mi, (s, e)) in results.iter().enumerate() {
                if best_individual[mi].as_ref().map_or(true, |(bs, _, _)| s > bs) {
                    best_individual[mi] = Some((*s, *cfg, e.clone()));
                    improved_level = true;
                }
            }
            if best_common.as_ref().map_or(true, |(bs, _, _)| mean > *bs) {
                best_common = Some((mean, *cfg, results));
                improved_level = true;
            }
            // Cancellation check *after* the evaluation so at least one
            // candidate is always scored and the families are populated.
            let best_score =
                best_common.as_ref().map(|(s, _, _)| *s).unwrap_or(f64::NEG_INFINITY);
            let elapsed = t0.elapsed();
            let go = sink.on_progress(&Progress {
                phase: "global",
                elapsed,
                points: evaluated,
                best_score,
                rate: Progress::rate_of(evaluated, elapsed),
                depth: 1,
            });
            if !go || cancelled {
                cancelled = true;
                break 'levels;
            }
        }
        if opts.no_prune {
            continue; // unpruned arm: exhaust the pool
        }
        if improved_level {
            worse_levels = 0;
        } else {
            worse_levels += 1;
            if worse_levels > opts.hysteresis {
                break 'levels;
            }
        }
    }

    // ---- 3. Assemble the three families ---------------------------------
    let (_, common_cfg, common_evals) = best_common.expect("pool non-empty");
    let common = (
        common_cfg,
        models
            .iter()
            .zip(&common_evals)
            .map(|(p, (_, e))| ModelPipelineResult {
                model: p.name.clone(),
                configs: vec![common_cfg; p.stages.len()],
                eval: e.clone(),
            })
            .collect(),
    );
    let individual: Vec<ModelPipelineResult> = models
        .iter()
        .zip(&best_individual)
        .map(|(p, b)| {
            let (_, cfg, e) = b.as_ref().expect("every model evaluated");
            ModelPipelineResult {
                model: p.name.clone(),
                configs: vec![*cfg; p.stages.len()],
                eval: e.clone(),
            }
        })
        .collect();
    let mosaic: Vec<ModelPipelineResult> = models
        .iter()
        .enumerate()
        .map(|(mi, p)| {
            let cfgs = mosaic_cfgs[mi].clone();
            let times: Vec<StageTimes> = p
                .stages
                .iter()
                .zip(&cfgs)
                .map(|(s, c)| stage_times(s, c, p.tmp, net, backend))
                .collect();
            let e = simulate_with_times(p, &cfgs, &times, opts.scheme, net);
            ModelPipelineResult { model: p.name.clone(), configs: cfgs, eval: e }
        })
        .collect();

    GlobalResult {
        common,
        individual,
        mosaic,
        candidates_evaluated: evaluated,
        candidate_pool,
        wall: t0.elapsed(),
        local_searches,
        cancelled,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;
    use crate::distributed::partition::partition_transformer;
    use crate::graph::autodiff::Optimizer;

    fn mini_models() -> Vec<PartitionedModel> {
        let mut a = crate::models::transformer::gpt2_xl();
        a.layers = 8;
        let mut b = crate::models::transformer::opt_1_3b();
        b.layers = 8;
        vec![
            partition_transformer("mini-gpt2", &a, 4, 1, Optimizer::SgdMomentum),
            partition_transformer("mini-opt", &b, 4, 1, Optimizer::SgdMomentum),
        ]
    }

    #[test]
    fn produces_three_families() {
        let ms = mini_models();
        let r = global_search(&ms, &GlobalOptions::default(), &Network::default(), &mut NativeCost);
        assert_eq!(r.individual.len(), 2);
        assert_eq!(r.mosaic.len(), 2);
        assert_eq!(r.common.1.len(), 2);
        assert!(r.candidate_pool >= 1);
        assert!(r.candidates_evaluated >= 1);
        // Stage dedup: 8 identical middle layers across 4 stages means
        // far fewer local searches than stages.
        assert!(r.local_searches <= 6, "local searches {}", r.local_searches);
    }

    #[test]
    fn individual_at_least_as_good_as_common_per_model() {
        let ms = mini_models();
        let r = global_search(&ms, &GlobalOptions::default(), &Network::default(), &mut NativeCost);
        for (ind, com) in r.individual.iter().zip(&r.common.1) {
            assert!(
                ind.eval.throughput >= com.eval.throughput * 0.999,
                "{}: individual {} < common {}",
                ind.model,
                ind.eval.throughput,
                com.eval.throughput
            );
        }
    }

    #[test]
    fn observed_cancellation_still_populates_families() {
        let ms = mini_models();
        let mut sink = crate::api::progress::DeadlineSink::new(std::time::Duration::ZERO);
        let r = global_search_observed(
            &ms,
            &GlobalOptions::default(),
            &Network::default(),
            &mut NativeCost,
            &NoSharedCache,
            &mut sink,
        );
        assert!(r.cancelled, "zero deadline must cancel");
        assert_eq!(r.common.1.len(), 2);
        assert_eq!(r.individual.len(), 2);
        assert_eq!(r.mosaic.len(), 2);
        assert!(r.candidates_evaluated >= 1, "one candidate is always scored");
        let full =
            global_search(&ms, &GlobalOptions::default(), &Network::default(), &mut NativeCost);
        assert!(!full.cancelled);
        assert!(full.candidates_evaluated >= r.candidates_evaluated);
    }

    #[test]
    fn parallel_local_searches_match_serial() {
        let ms = mini_models();
        let serial =
            global_search(&ms, &GlobalOptions::default(), &Network::default(), &mut NativeCost);
        let jopts = GlobalOptions { jobs: 4, ..Default::default() };
        let par = global_search(&ms, &jopts, &Network::default(), &mut NativeCost);
        assert_eq!(par.common.0, serial.common.0, "common config must not depend on --jobs");
        assert_eq!(par.candidate_pool, serial.candidate_pool);
        assert_eq!(par.candidates_evaluated, serial.candidates_evaluated);
        assert_eq!(par.local_searches, serial.local_searches);
        for (a, b) in par.individual.iter().zip(&serial.individual) {
            assert_eq!(a.configs, b.configs);
            assert_eq!(a.eval.throughput, b.eval.throughput);
        }
        for (a, b) in par.mosaic.iter().zip(&serial.mosaic) {
            assert_eq!(a.configs, b.configs);
        }
    }

    #[test]
    fn mosaic_configs_vary_per_stage_shape() {
        let ms = mini_models();
        let r = global_search(&ms, &GlobalOptions::default(), &Network::default(), &mut NativeCost);
        for m in &r.mosaic {
            assert_eq!(m.configs.len(), 4);
        }
    }
}
