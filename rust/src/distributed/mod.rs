//! Distributed training support (paper section 5): pipeline/TMP
//! partitioning, interconnect modeling, pipeline-schedule simulation, and
//! the global top-k architecture search.
//!
//! * [`network`] — p2p activation transfers and ring all-reduce;
//! * [`partition`] — the memory-balanced pipeline splitter (proof-of-
//!   concept placement of section 5, HBM-capacity based);
//! * [`pipeline`] — GPipe / PipeDream-1F1B iteration-time and memory
//!   simulation over per-stage compute times;
//! * [`global_search`] — the top-k-per-stage global architecture search
//!   with the area-ordered tree pruner (section 5.1).
//!
//! The cluster-level extensions — hierarchical topologies, the
//! discrete-event schedule simulator, and the parallelism-strategy
//! auto-sweep — live in [`crate::cluster`]; the flat [`network`] model
//! is its single-hop special case.

pub mod data_parallel;
pub mod global_search;
pub mod network;
pub mod partition;
pub mod pipeline;

/// Pipeline training scheme (section 2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Flush-at-end pipelining; all microbatch activations stashed.
    GPipe,
    /// PipeDream-1F1B: steady-state one-forward-one-backward; at most
    /// `stages` microbatches in flight per stage.
    PipeDream1F1B,
}

impl std::str::FromStr for Scheme {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gpipe" => Ok(Scheme::GPipe),
            "pipedream" | "1f1b" => Ok(Scheme::PipeDream1F1B),
            other => Err(format!("unknown pipeline scheme {other:?}")),
        }
    }
}
