//! # WHAM — Workload-Aware Hardware Accelerator Mining
//!
//! Reproduction of *"Workload-Aware Hardware Accelerator Mining for
//! Distributed Deep Learning Training"* (CS.AR 2024).
//!
//! WHAM searches hardware-accelerator configurations
//! `<#TC, TC-Dim, #VC, VC-Width>` that maximize end-to-end **training**
//! throughput or Perf/TDP, for single accelerators and for pipeline /
//! tensor-model-parallel distributed training.
//!
//! The crate is the Layer-3 rust coordinator of a three-layer stack:
//! the operator cost model (Layer-1 Pallas kernel wrapped by a Layer-2
//! JAX estimator) is AOT-compiled to `artifacts/cost_model.hlo.txt` and
//! executed via PJRT ([`runtime`]); a bit-compatible native mirror lives
//! in [`cost::native`]. Python never runs on the search path.
//!
//! Module map (see DESIGN.md for the full inventory):
//! * [`api`] — the typed request/plan/reply layer every front door
//!   (CLI, HTTP service, library callers) shares: request builders,
//!   validation, wire codec, [`api::Session`], progress/cancellation
//! * [`graph`] — training operator-graph IR + mirrored autodiff + fusion
//! * [`models`] — the 11-workload zoo of Table 4
//! * [`workload`] — declarative JSON workload specs, shape-inference
//!   lowering onto the same IR, and the layered registry (builtin specs,
//!   `--workload-dir`, service uploads) behind `resolve_workload`
//! * [`arch`] — architectural template, area/power, TPUv2/NVDLA presets
//! * [`cost`] — architecture estimator (native + PJRT backends)
//! * [`sched`] — ASAP/ALAP, criticality, greedy list scheduler
//! * [`search`] — MCR heuristics (Alg. 1), config pruner (Alg. 2), B&B
//!   ILP, dimension generator, WHAM-common, top-k
//! * [`baselines`] — ConfuciuX+, Spotlight+, hand-optimized designs
//! * [`distributed`] — pipeline partitioner, Megatron TMP, GPipe/1F1B
//!   simulation, interconnect model, global top-k search
//! * [`cluster`] — hierarchical topologies with routed collective
//!   costs, the discrete-event pipeline simulator (GPipe / 1F1B /
//!   interleaved-1F1B), and the (pp, tp, dp) strategy auto-sweep
//! * [`jobs`] — durable async job tier: crash-safe JSONL write-ahead
//!   store, bounded dispatcher with per-client quotas and retry, SSE
//!   progress fan-out, graceful drain
//! * [`runtime`] — PJRT client wrapper for the AOT artifacts
//! * [`coordinator`] — parallel per-stage search orchestration
//! * [`service`] — the `wham serve` mining service: HTTP front end,
//!   request coalescing, persistent fingerprint-keyed design database
//! * [`telemetry`] — span tracing (Chrome-trace/Perfetto output), the
//!   unified metrics registry behind `GET /metrics`, and the search
//!   flight recorder (`wham trace explain`)
//! * [`metrics`], [`report`], [`util`] — supporting substrates

pub mod api;
pub mod arch;
pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod cost;
pub mod distributed;
pub mod graph;
pub mod jobs;
pub mod metrics;
pub mod models;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod search;
pub mod service;
pub mod telemetry;
pub mod util;
pub mod workload;

pub use api::{
    ApiError, CommonRequest, EvaluateRequest, FromJson, GlobalRequest, SearchRequest, Session,
    ToJson,
};
pub use arch::{ArchConfig, Constraints};
pub use graph::{fingerprint, CoreType, Fingerprint, OpKind, OperatorGraph};
pub use metrics::Metric;
pub use search::engine::{EvalCache, SearchResult, WhamSearch};
