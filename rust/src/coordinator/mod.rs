//! Search coordinator: fans per-workload searches out over OS threads
//! (std::thread::scope — the offline cache carries no async runtime; see
//! DESIGN.md substitutions), collects results in submission order, and
//! owns the cost-backend selection policy.
//!
//! PJRT note: `xla::PjRtClient` wraps a thread-pool-backed CPU client
//! that is not `Sync`, so each worker thread builds its own backend via
//! the factory rather than sharing one.

use crate::cost::native::NativeCost;
use crate::cost::CostBackend;
use crate::graph::OperatorGraph;
use crate::search::engine::{SearchOptions, SearchResult, WhamSearch};

/// Which estimator backend searches use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    /// Pure-rust mirror (always available).
    Native,
    /// AOT artifact through PJRT (requires `make artifacts`).
    Pjrt,
    /// PJRT when the artifact exists, else native.
    Auto,
}

impl std::str::FromStr for BackendChoice {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "native" => Ok(Self::Native),
            "pjrt" | "xla" => Ok(Self::Pjrt),
            "auto" => Ok(Self::Auto),
            other => Err(format!("unknown backend {other:?}")),
        }
    }
}

/// Build a cost backend per the choice. Errors only for explicit `Pjrt`
/// without artifacts.
pub fn make_backend(choice: BackendChoice) -> anyhow::Result<Box<dyn CostBackend>> {
    match choice {
        BackendChoice::Native => Ok(Box::new(NativeCost)),
        BackendChoice::Pjrt => Ok(Box::new(crate::cost::xla_rt::XlaCost::from_artifacts()?)),
        BackendChoice::Auto => match crate::cost::xla_rt::XlaCost::from_artifacts() {
            Ok(b) => Ok(Box::new(b)),
            Err(_) => Ok(Box::new(NativeCost)),
        },
    }
}

/// A unit of search work.
pub struct SearchJob {
    pub name: String,
    pub graph: OperatorGraph,
    pub batch: u64,
    pub opts: SearchOptions,
}

/// Run jobs across up to `workers` threads, each with its own backend
/// from `choice`. Results return in job order.
///
/// Failures are per-job `Err`s, not panics: a worker whose backend fails
/// to construct (or whose search panics) reports the error for the jobs
/// it claimed while the remaining workers keep draining the queue — one
/// bad backend no longer poisons the whole scoped run.
pub fn run_parallel(
    jobs: Vec<SearchJob>,
    choice: BackendChoice,
    workers: usize,
) -> Vec<(String, anyhow::Result<SearchResult>)> {
    let workers = workers.clamp(1, jobs.len().max(1));
    let n = jobs.len();
    let jobs: Vec<Option<SearchJob>> = jobs.into_iter().map(Some).collect();
    let jobs = std::sync::Mutex::new(jobs);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let results: Vec<std::sync::Mutex<Option<(String, anyhow::Result<SearchResult>)>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                // Construct lazily so a worker that never claims a job
                // never pays for (or fails on) a backend.
                let mut backend: Option<Box<dyn CostBackend>> = None;
                let mut backend_err: Option<String> = None;
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let Some(job) = jobs.lock().unwrap()[i].take() else { continue };
                    if backend.is_none() && backend_err.is_none() {
                        match make_backend(choice) {
                            Ok(b) => backend = Some(b),
                            Err(e) => backend_err = Some(e.to_string()),
                        }
                    }
                    let out = match backend.as_mut() {
                        Some(b) => std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            WhamSearch::new(&job.graph, job.batch, job.opts).run(b.as_mut())
                        }))
                        .map_err(|p| {
                            anyhow::anyhow!(
                                "search for {:?} panicked: {}",
                                job.name,
                                crate::util::panic_text(&p)
                            )
                        }),
                        None => Err(anyhow::anyhow!(
                            "backend construction failed in worker: {}",
                            backend_err.as_deref().unwrap_or("unknown error")
                        )),
                    };
                    *results[i].lock().unwrap() = Some((job.name, out));
                }
            });
        }
    });

    results
        .into_iter()
        .map(|m| {
            m.into_inner().unwrap().unwrap_or_else(|| {
                ("<unclaimed>".to_string(), Err(anyhow::anyhow!("job was never executed")))
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::{training_graph, Optimizer};

    fn job(name: &str, layers: std::ops::Range<u64>) -> SearchJob {
        let fwd = crate::models::transformer::forward_range(
            &crate::models::transformer::bert_base(),
            layers.start,
            layers.end,
        );
        SearchJob {
            name: name.into(),
            graph: training_graph(&fwd, Optimizer::SgdMomentum),
            batch: 4,
            opts: SearchOptions::default(),
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let serial = run_parallel(vec![job("a", 0..1)], BackendChoice::Native, 1);
        let parallel = run_parallel(
            vec![job("a", 0..1), job("b", 0..2), job("c", 1..2)],
            BackendChoice::Native,
            3,
        );
        assert_eq!(parallel.len(), 3);
        assert_eq!(parallel[0].0, "a");
        assert_eq!(
            parallel[0].1.as_ref().unwrap().best.config,
            serial[0].1.as_ref().unwrap().best.config
        );
        assert_eq!(parallel[2].0, "c");
        assert!(parallel.iter().all(|(_, r)| r.is_ok()));
    }

    #[test]
    fn backend_failure_is_a_per_job_error_not_a_panic() {
        // With no PJRT artifacts installed, explicit-PJRT jobs must come
        // back as per-job `Err`s (the old code panicked the scoped run).
        // When artifacts *are* installed this degrades to asserting
        // success — panic-free either way.
        let rs = run_parallel(vec![job("a", 0..1), job("b", 1..2)], BackendChoice::Pjrt, 2);
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].0, "a");
        assert_eq!(rs[1].0, "b");
        if let Err(e) = &rs[0].1 {
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn auto_backend_constructs() {
        assert!(make_backend(BackendChoice::Auto).is_ok());
        assert!(make_backend(BackendChoice::Native).is_ok());
    }

    #[test]
    fn zero_jobs_is_fine() {
        let r = run_parallel(Vec::new(), BackendChoice::Native, 4);
        assert!(r.is_empty());
    }
}
