//! Search coordinator: fans per-workload searches out over OS threads
//! (std::thread::scope — the offline cache carries no async runtime; see
//! DESIGN.md substitutions), collects results in submission order, and
//! owns the cost-backend selection policy.
//!
//! PJRT note: `xla::PjRtClient` wraps a thread-pool-backed CPU client
//! that is not `Sync`, so each worker thread builds its own backend via
//! the factory rather than sharing one.

use crate::cost::native::NativeCost;
use crate::cost::CostBackend;
use crate::graph::OperatorGraph;
use crate::search::engine::{SearchOptions, SearchResult, WhamSearch};

/// Which estimator backend searches use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    /// Pure-rust mirror (always available).
    Native,
    /// AOT artifact through PJRT (requires `make artifacts`).
    Pjrt,
    /// PJRT when the artifact exists, else native.
    Auto,
}

impl std::str::FromStr for BackendChoice {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "native" => Ok(Self::Native),
            "pjrt" | "xla" => Ok(Self::Pjrt),
            "auto" => Ok(Self::Auto),
            other => Err(format!("unknown backend {other:?}")),
        }
    }
}

/// Build a cost backend per the choice. Errors only for explicit `Pjrt`
/// without artifacts.
pub fn make_backend(choice: BackendChoice) -> anyhow::Result<Box<dyn CostBackend>> {
    match choice {
        BackendChoice::Native => Ok(Box::new(NativeCost)),
        BackendChoice::Pjrt => Ok(Box::new(crate::cost::xla_rt::XlaCost::from_artifacts()?)),
        BackendChoice::Auto => match crate::cost::xla_rt::XlaCost::from_artifacts() {
            Ok(b) => Ok(Box::new(b)),
            Err(_) => Ok(Box::new(NativeCost)),
        },
    }
}

/// A unit of search work.
pub struct SearchJob {
    pub name: String,
    pub graph: OperatorGraph,
    pub batch: u64,
    pub opts: SearchOptions,
}

/// Run jobs across up to `workers` threads, each with its own backend
/// from `choice`. Results return in job order.
pub fn run_parallel(
    jobs: Vec<SearchJob>,
    choice: BackendChoice,
    workers: usize,
) -> Vec<(String, SearchResult)> {
    let workers = workers.clamp(1, jobs.len().max(1));
    let n = jobs.len();
    let jobs: Vec<Option<SearchJob>> = jobs.into_iter().map(Some).collect();
    let jobs = std::sync::Mutex::new(jobs);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let results: Vec<std::sync::Mutex<Option<(String, SearchResult)>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut backend =
                    make_backend(choice).expect("backend construction failed in worker");
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let job = jobs.lock().unwrap()[i].take().expect("job taken twice");
                    let r = WhamSearch::new(&job.graph, job.batch, job.opts)
                        .run(backend.as_mut());
                    *results[i].lock().unwrap() = Some((job.name, r));
                }
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed every job"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::{training_graph, Optimizer};

    fn job(name: &str, layers: std::ops::Range<u64>) -> SearchJob {
        let fwd = crate::models::transformer::forward_range(
            &crate::models::transformer::bert_base(),
            layers.start,
            layers.end,
        );
        SearchJob {
            name: name.into(),
            graph: training_graph(&fwd, Optimizer::SgdMomentum),
            batch: 4,
            opts: SearchOptions::default(),
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let serial = run_parallel(vec![job("a", 0..1)], BackendChoice::Native, 1);
        let parallel = run_parallel(
            vec![job("a", 0..1), job("b", 0..2), job("c", 1..2)],
            BackendChoice::Native,
            3,
        );
        assert_eq!(parallel.len(), 3);
        assert_eq!(parallel[0].0, "a");
        assert_eq!(parallel[0].1.best.config, serial[0].1.best.config);
        assert_eq!(parallel[2].0, "c");
    }

    #[test]
    fn auto_backend_constructs() {
        assert!(make_backend(BackendChoice::Auto).is_ok());
        assert!(make_backend(BackendChoice::Native).is_ok());
    }

    #[test]
    fn zero_jobs_is_fine() {
        let r = run_parallel(Vec::new(), BackendChoice::Native, 4);
        assert!(r.is_empty());
    }
}
