//! Baseline search frameworks (paper section 6.2): ConfuciuX+ and
//! Spotlight+ — the inference-era searchers extended to training — plus
//! the hand-optimized TPUv2/NVDLA presets (re-exported from
//! [`crate::arch::presets`]).
//!
//! Both baselines optimize over the *same* architectural template and
//! cost model as WHAM, so every comparison isolates the search technique:
//! * ConfuciuX+ — RL (REINFORCE-style policy over discrete parameter
//!   choices) followed by genetic-algorithm fine-tuning; like the
//!   original, it sizes tensor-operator needs per pass and keeps the
//!   largest configuration across forward/backward/update;
//! * Spotlight+ — domain-aware Bayesian optimization (expected
//!   improvement over a nearest-neighbour surrogate on a normalized
//!   parameter space) optimizing the backward and update passes alongside
//!   the forward pass; the vector width is tied to the tensor-core
//!   height, as the paper does for frameworks that ignore vector ops.

pub mod confuciux;
pub mod spotlight;

use crate::arch::ArchConfig;
use crate::metrics::Evaluation;

/// A baseline's search outcome, with its full evaluation trace.
#[derive(Debug, Clone)]
pub struct BaselineResult {
    pub config: ArchConfig,
    pub eval: Evaluation,
    pub score: f64,
    /// Configurations evaluated (the 500-iteration budget of Fig. 8).
    pub evaluations: usize,
    pub wall: std::time::Duration,
    /// (iteration, best-so-far score) convergence log.
    pub trajectory: Vec<(usize, f64)>,
}

/// Shared objective wrapper: evaluate a config on the training graph.
pub(crate) fn objective(
    graph: &crate::graph::OperatorGraph,
    batch: u64,
    backend: &mut dyn crate::cost::CostBackend,
    metric: crate::metrics::Metric,
    constraints: &crate::arch::Constraints,
    config: &ArchConfig,
) -> (f64, Evaluation) {
    let eval = crate::search::engine::evaluate_design(graph, batch, config, backend);
    if !constraints.allows(config) {
        // Infeasible designs rank below everything feasible.
        return (f64::NEG_INFINITY, eval);
    }
    (metric.score(&eval, 0.0), eval)
}
