//! ConfuciuX+ — the RL + genetic-algorithm searcher of Kao et al.
//! (MICRO'20), extended to training as the paper does (section 6.2):
//! the framework sizes tensor-operator requirements for the forward,
//! backward, and weight-update passes and keeps the **largest**
//! configuration across passes; vector width is tied to TC height.
//!
//! Phase 1 (RL): REINFORCE-style categorical policy over the discrete
//! parameter menu, updated towards configurations that beat the running
//! baseline. Phase 2 (GA): population seeded from the policy's best,
//! tournament selection + crossover + mutation fine-tunes the minimum —
//! matching the paper's observation that "the RL converges to a local
//! minimum relatively quickly, while the genetic algorithm takes a long
//! time to fine-tune".

use std::time::Instant;

use super::BaselineResult;
use crate::arch::{ArchConfig, Constraints};
use crate::cost::CostBackend;
use crate::graph::OperatorGraph;
use crate::metrics::Metric;
use crate::util::rng::Rng;

/// Discrete menus per template parameter.
const DIMS: [u64; 7] = [4, 8, 16, 32, 64, 128, 256];
const COUNTS: [u64; 9] = [1, 2, 3, 4, 6, 8, 12, 16, 24];

/// Tunables of the baseline.
#[derive(Debug, Clone, Copy)]
pub struct ConfuciuxOpts {
    pub iterations: usize,
    pub rl_fraction: f64,
    pub population: usize,
    pub seed: u64,
    pub metric: Metric,
    pub constraints: Constraints,
}

impl Default for ConfuciuxOpts {
    fn default() -> Self {
        Self {
            iterations: 500,
            rl_fraction: 0.4,
            population: 16,
            seed: 0xC0FFEE,
            metric: Metric::Throughput,
            constraints: Constraints::default(),
        }
    }
}

/// Genome: indices into the parameter menus.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Genome {
    tc_x: usize,
    tc_y: usize,
    num_tc: usize,
}

impl Genome {
    fn to_config(self) -> ArchConfig {
        let tc_x = DIMS[self.tc_x];
        let tc_y = DIMS[self.tc_y];
        // ConfuciuX ignores vector ops: VC width mirrors TC height and
        // one VC per TC (section 6.2 extension rule).
        ArchConfig {
            num_tc: COUNTS[self.num_tc],
            tc_x,
            tc_y,
            num_vc: COUNTS[self.num_tc],
            vc_w: tc_x,
        }
    }
}

/// Run ConfuciuX+ on a training graph.
pub fn run(
    graph: &OperatorGraph,
    batch: u64,
    backend: &mut dyn CostBackend,
    opts: ConfuciuxOpts,
) -> BaselineResult {
    let t0 = Instant::now();
    let mut rng = Rng::new(opts.seed);
    let mut evals = 0usize;
    let mut best: Option<(f64, Genome, crate::metrics::Evaluation)> = None;
    let mut trajectory = Vec::new();

    let score_of = |g: Genome, backend: &mut dyn CostBackend, evals: &mut usize| {
        *evals += 1;
        let cfg = g.to_config();
        super::objective(graph, batch, backend, opts.metric, &opts.constraints, &cfg)
    };

    // ---- Phase 1: REINFORCE over categorical logits --------------------
    let rl_iters = (opts.iterations as f64 * opts.rl_fraction) as usize;
    let mut logits_x = [0.0f64; DIMS.len()];
    let mut logits_y = [0.0f64; DIMS.len()];
    let mut logits_n = [0.0f64; COUNTS.len()];
    let mut baseline = 0.0f64;
    let sample = |logits: &[f64], rng: &mut Rng| -> usize {
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let ws: Vec<f64> = logits.iter().map(|l| (l - mx).exp()).collect();
        let total: f64 = ws.iter().sum();
        let mut u = rng.f64() * total;
        for (i, w) in ws.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        ws.len() - 1
    };
    for it in 0..rl_iters {
        let g = Genome {
            tc_x: sample(&logits_x, &mut rng),
            tc_y: sample(&logits_y, &mut rng),
            num_tc: sample(&logits_n, &mut rng),
        };
        let (s, eval) = score_of(g, backend, &mut evals);
        if best.as_ref().map_or(true, |(bs, _, _)| s > *bs) {
            best = Some((s, g, eval));
        }
        trajectory.push((it, best.as_ref().unwrap().0));
        // Policy-gradient step on the advantage (normalized to the
        // running baseline to keep the learning rate scale-free).
        let adv = if baseline == 0.0 { 0.0 } else { (s - baseline) / baseline.abs().max(1e-9) };
        baseline = if it == 0 { s } else { 0.9 * baseline + 0.1 * s };
        let lr = 0.5;
        logits_x[g.tc_x] += lr * adv.clamp(-2.0, 2.0);
        logits_y[g.tc_y] += lr * adv.clamp(-2.0, 2.0);
        logits_n[g.num_tc] += lr * adv.clamp(-2.0, 2.0);
    }

    // ---- Phase 2: genetic fine-tuning -----------------------------------
    let ga_iters = opts.iterations - rl_iters;
    let mut pop: Vec<(f64, Genome)> = Vec::with_capacity(opts.population);
    let best_seed = best.map(|(_, g, _)| g).unwrap_or(Genome { tc_x: 6, tc_y: 6, num_tc: 0 });
    for i in 0..opts.population {
        let g = if i == 0 {
            best_seed
        } else {
            Genome {
                tc_x: rng.below(DIMS.len()),
                tc_y: rng.below(DIMS.len()),
                num_tc: rng.below(COUNTS.len()),
            }
        };
        let (s, eval) = score_of(g, backend, &mut evals);
        if best.as_ref().map_or(true, |(bs, _, _)| s > *bs) {
            best = Some((s, g, eval));
        }
        pop.push((s, g));
    }
    let mut it = rl_iters + opts.population;
    while it < rl_iters + ga_iters {
        // Tournament selection of two parents.
        let pick = |rng: &mut Rng, pop: &[(f64, Genome)]| {
            let a = pop[rng.below(pop.len())];
            let b = pop[rng.below(pop.len())];
            if a.0 >= b.0 {
                a.1
            } else {
                b.1
            }
        };
        let pa = pick(&mut rng, &pop);
        let pb = pick(&mut rng, &pop);
        // Uniform crossover + point mutation.
        let mut child = Genome {
            tc_x: if rng.chance(0.5) { pa.tc_x } else { pb.tc_x },
            tc_y: if rng.chance(0.5) { pa.tc_y } else { pb.tc_y },
            num_tc: if rng.chance(0.5) { pa.num_tc } else { pb.num_tc },
        };
        if rng.chance(0.3) {
            match rng.below(3) {
                0 => child.tc_x = rng.below(DIMS.len()),
                1 => child.tc_y = rng.below(DIMS.len()),
                _ => child.num_tc = rng.below(COUNTS.len()),
            }
        }
        let (s, eval) = score_of(child, backend, &mut evals);
        if best.as_ref().map_or(true, |(bs, _, _)| s > *bs) {
            best = Some((s, child, eval));
        }
        trajectory.push((it, best.as_ref().unwrap().0));
        // Steady-state replacement of the worst member.
        let worst = pop
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.total_cmp(&b.1 .0))
            .map(|(i, _)| i)
            .unwrap();
        if s > pop[worst].0 {
            pop[worst] = (s, child);
        }
        it += 1;
    }

    let (score, genome, eval) = best.expect("at least one evaluation");
    BaselineResult {
        config: genome.to_config(),
        eval,
        score,
        evaluations: evals,
        wall: t0.elapsed(),
        trajectory,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;
    use crate::graph::autodiff::{training_graph, Optimizer};

    fn small_graph() -> OperatorGraph {
        let fwd = crate::models::transformer::forward_range(&crate::models::transformer::bert_base(), 0, 1);
        training_graph(&fwd, Optimizer::SgdMomentum)
    }

    #[test]
    fn finds_feasible_design() {
        let g = small_graph();
        let opts = ConfuciuxOpts { iterations: 60, ..Default::default() };
        let r = run(&g, 4, &mut NativeCost, opts);
        assert!(r.config.in_template());
        assert!(opts.constraints.allows(&r.config));
        assert!(r.score > 0.0);
        assert!(r.evaluations >= 60);
    }

    #[test]
    fn deterministic_under_seed() {
        let g = small_graph();
        let opts = ConfuciuxOpts { iterations: 40, ..Default::default() };
        let a = run(&g, 4, &mut NativeCost, opts);
        let b = run(&g, 4, &mut NativeCost, opts);
        assert_eq!(a.config, b.config);
        assert_eq!(a.score, b.score);
    }

    #[test]
    fn trajectory_monotone() {
        let g = small_graph();
        let r = run(&g, 4, &mut NativeCost, ConfuciuxOpts { iterations: 50, ..Default::default() });
        for w in r.trajectory.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn vc_mirrors_tc() {
        let g = small_graph();
        let r = run(&g, 4, &mut NativeCost, ConfuciuxOpts { iterations: 30, ..Default::default() });
        assert_eq!(r.config.vc_w, r.config.tc_x);
        assert_eq!(r.config.num_vc, r.config.num_tc);
    }
}
