//! Spotlight+ — the domain-aware Bayesian-optimization searcher of
//! Sakhuja et al. (HPCA'23), extended to training (paper section 6.2):
//! the acquisition optimizes forward + backward + weight-update cost
//! jointly. Spotlight's domain information dedupes repeated problem
//! dimensions (transformer layers share shapes), which we mirror by
//! deduplicating identical cost rows before evaluation — this is why
//! Spotlight+ converges faster than ConfuciuX+ on language models
//! (Fig. 8) while still exploring far more configs than WHAM.
//!
//! Surrogate: distance-weighted nearest-neighbour regression in the
//! normalized (log2 tc_x, log2 tc_y, log2 #cores) space with an
//! expected-improvement-style acquisition over random candidates — a
//! faithful lightweight stand-in for the paper's GP-BO (the offline
//! cache has no linear-algebra stack; behaviourally both are
//! sample-then-maximize-acquisition loops over the same space).

use std::time::Instant;

use super::BaselineResult;
use crate::arch::{ArchConfig, Constraints};
use crate::cost::CostBackend;
use crate::graph::OperatorGraph;
use crate::metrics::Metric;
use crate::util::rng::Rng;

/// Tunables.
#[derive(Debug, Clone, Copy)]
pub struct SpotlightOpts {
    pub iterations: usize,
    /// Random warm-up samples before the surrogate drives.
    pub warmup: usize,
    /// Acquisition candidates scored per iteration.
    pub candidates: usize,
    pub seed: u64,
    pub metric: Metric,
    pub constraints: Constraints,
}

impl Default for SpotlightOpts {
    fn default() -> Self {
        Self {
            iterations: 500,
            warmup: 24,
            candidates: 64,
            seed: 0x5EED,
            metric: Metric::Throughput,
            constraints: Constraints::default(),
        }
    }
}

/// Search point in normalized space: (log2 tc_x, log2 tc_y, log2 cores).
#[derive(Debug, Clone, Copy, PartialEq)]
struct Point {
    lx: f64,
    ly: f64,
    lc: f64,
}

impl Point {
    fn random(rng: &mut Rng) -> Self {
        Self {
            lx: 2.0 + rng.f64() * 6.0, // 4..256
            ly: 2.0 + rng.f64() * 6.0,
            lc: rng.f64() * 4.0, // 1..16 cores
        }
    }

    fn jitter(self, rng: &mut Rng, scale: f64) -> Self {
        Self {
            lx: (self.lx + rng.normal() * scale).clamp(2.0, 8.0),
            ly: (self.ly + rng.normal() * scale).clamp(2.0, 8.0),
            lc: (self.lc + rng.normal() * scale).clamp(0.0, 4.0),
        }
    }

    fn to_config(self) -> ArchConfig {
        let snap = |l: f64| -> u64 { 1u64 << (l.round() as u32).clamp(2, 8) };
        let cores = (self.lc.exp2().round() as u64).clamp(1, 16);
        let tc_x = snap(self.lx);
        // Spotlight ignores vector ops: VC width follows the TC width
        // (section 6.2 extension rule), one VC per TC.
        ArchConfig { num_tc: cores, tc_x, tc_y: snap(self.ly), num_vc: cores, vc_w: tc_x }
    }

    fn dist2(&self, o: &Point) -> f64 {
        (self.lx - o.lx).powi(2) + (self.ly - o.ly).powi(2) + (self.lc - o.lc).powi(2)
    }
}

/// Distance-weighted surrogate prediction with an uncertainty proxy.
fn surrogate(history: &[(Point, f64)], p: &Point) -> (f64, f64) {
    let mut wsum = 0.0;
    let mut vsum = 0.0;
    let mut dmin = f64::INFINITY;
    for (hp, hv) in history {
        let d2 = p.dist2(hp);
        dmin = dmin.min(d2);
        let w = 1.0 / (d2 + 1e-3);
        wsum += w;
        vsum += w * hv;
    }
    (vsum / wsum, dmin.sqrt())
}

/// Run Spotlight+ on a training graph.
pub fn run(
    graph: &OperatorGraph,
    batch: u64,
    backend: &mut dyn CostBackend,
    opts: SpotlightOpts,
) -> BaselineResult {
    let t0 = Instant::now();
    let mut rng = Rng::new(opts.seed);

    // Domain information: dedupe repeated problem dimensions before the
    // expensive objective (Spotlight's key trick).
    let dedup = dedup_graph(graph);
    let eval_graph = dedup.as_ref().unwrap_or(graph);

    let mut evals = 0usize;
    let mut history: Vec<(Point, f64)> = Vec::new();
    let mut best: Option<(f64, Point, crate::metrics::Evaluation)> = None;
    let mut trajectory = Vec::new();

    let measure = |p: Point, backend: &mut dyn CostBackend, evals: &mut usize| {
        *evals += 1;
        let cfg = p.to_config();
        super::objective(eval_graph, batch, backend, opts.metric, &opts.constraints, &cfg)
    };

    for it in 0..opts.iterations {
        let p = if it < opts.warmup || history.len() < 4 {
            Point::random(&mut rng)
        } else {
            // Acquisition: expected-improvement proxy mean + exploration
            // bonus over a candidate pool (random + jittered incumbents).
            let incumbent = best.as_ref().map(|(_, p, _)| *p).unwrap();
            let mut best_cand = Point::random(&mut rng);
            let mut best_acq = f64::NEG_INFINITY;
            for c in 0..opts.candidates {
                let cand = if c % 2 == 0 {
                    Point::random(&mut rng)
                } else {
                    incumbent.jitter(&mut rng, 0.7)
                };
                let (mu, sigma) = surrogate(&history, &cand);
                let acq = mu + 0.8 * sigma;
                if acq > best_acq {
                    best_acq = acq;
                    best_cand = cand;
                }
            }
            best_cand
        };
        let (s, eval) = measure(p, backend, &mut evals);
        if s.is_finite() {
            history.push((p, s));
        }
        if best.as_ref().map_or(true, |(bs, _, _)| s > *bs) {
            best = Some((s, p, eval));
        }
        trajectory.push((it, best.as_ref().unwrap().0));
    }

    let (_, point, _) = best.expect("at least one evaluation");
    // Re-evaluate the winner on the FULL graph for honest reporting.
    let cfg = point.to_config();
    let (score, eval) =
        super::objective(graph, batch, backend, opts.metric, &opts.constraints, &cfg);
    BaselineResult { config: cfg, eval, score, evaluations: evals, wall: t0.elapsed(), trajectory }
}

/// Collapse duplicate cost rows: keep one representative op per distinct
/// (kind, m, n, k), preserving a serial chain (Spotlight optimizes
/// per-layer cost, not the schedule, so the chain suffices).
fn dedup_graph(g: &OperatorGraph) -> Option<OperatorGraph> {
    use std::collections::HashSet;
    let mut seen: HashSet<(i32, u64, u64, u64)> = HashSet::new();
    let mut keep: Vec<usize> = Vec::new();
    for (v, op) in g.ops.iter().enumerate() {
        let r = op.kind.cost_row();
        if seen.insert((r.kind, r.m, r.n, r.k)) {
            keep.push(v);
        }
    }
    if keep.len() == g.len() {
        return None; // nothing to dedupe
    }
    let mut out = OperatorGraph::default();
    let mut prev: Option<usize> = None;
    for &v in &keep {
        let mut op = g.ops[v].clone();
        op.fwd_peer = None; // peers point into the original graph
        let preds: &[usize] = match prev {
            Some(ref p) => std::slice::from_ref(p),
            None => &[],
        };
        prev = Some(out.push_op(op, preds));
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;
    use crate::graph::autodiff::{training_graph, Optimizer};

    fn small_graph() -> OperatorGraph {
        let fwd = crate::models::transformer::forward_range(&crate::models::transformer::bert_base(), 0, 2);
        training_graph(&fwd, Optimizer::SgdMomentum)
    }

    #[test]
    fn finds_feasible_design() {
        let g = small_graph();
        let opts = SpotlightOpts { iterations: 60, ..Default::default() };
        let r = run(&g, 4, &mut NativeCost, opts);
        assert!(r.config.in_template());
        assert!(r.score > 0.0);
    }

    #[test]
    fn dedup_shrinks_transformer_graphs() {
        let g = small_graph();
        let d = dedup_graph(&g).expect("two identical layers must dedupe");
        assert!(d.len() < g.len() / 1, "dedup kept {} of {}", d.len(), g.len());
        assert!(d.len() < g.len());
        crate::graph::validate::validate(&d).unwrap();
    }

    #[test]
    fn deterministic_under_seed() {
        let g = small_graph();
        let opts = SpotlightOpts { iterations: 30, ..Default::default() };
        let a = run(&g, 4, &mut NativeCost, opts);
        let b = run(&g, 4, &mut NativeCost, opts);
        assert_eq!(a.config, b.config);
    }

    #[test]
    fn surrogate_interpolates() {
        let h = vec![
            (Point { lx: 2.0, ly: 2.0, lc: 0.0 }, 1.0),
            (Point { lx: 8.0, ly: 8.0, lc: 4.0 }, 3.0),
        ];
        let (mu_near_a, _) = surrogate(&h, &Point { lx: 2.1, ly: 2.0, lc: 0.0 });
        let (mu_near_b, _) = surrogate(&h, &Point { lx: 7.9, ly: 8.0, lc: 4.0 });
        assert!(mu_near_a < mu_near_b);
    }
}
