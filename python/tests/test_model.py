"""Layer-2 estimator: aggregate semantics + shape contract."""

import jax.numpy as jnp
import numpy as np

from compile.model import N_OPS, estimate


def padded(rows):
    kind = np.full(N_OPS, -1, np.int32)
    m = np.ones(N_OPS, np.int32)
    n = np.ones(N_OPS, np.int32)
    k = np.ones(N_OPS, np.int32)
    for i, (ki, mi, ni, kk) in enumerate(rows):
        kind[i], m[i], n[i], k[i] = ki, mi, ni, kk
    return tuple(jnp.asarray(a) for a in (kind, m, n, k))


CFG = jnp.asarray([128, 128, 128], jnp.int32)


def test_shapes():
    lat, en, ut, tot = estimate(*padded([(0, 128, 128, 128)]), CFG)
    assert lat.shape == (N_OPS,) and en.shape == (N_OPS,) and ut.shape == (N_OPS,)
    assert tot.shape == (4,)


def test_totals_match_sums():
    rows = [(0, 512, 256, 128), (1, 9999, 2, 1), (2, 300, 300, 300)]
    lat, en, ut, tot = estimate(*padded(rows), CFG)
    np.testing.assert_allclose(float(tot[0]), float(jnp.sum(lat)), rtol=1e-6)
    np.testing.assert_allclose(float(tot[1]), float(jnp.sum(en)), rtol=1e-6)
    assert int(tot[3]) == len(rows)


def test_mean_util_ignores_padding():
    # One perfectly-utilized op; mean over valid ops must be ~1.0 even
    # though 4095 padding rows have util 0.
    _, _, _, tot = estimate(*padded([(0, 256, 256, 64)]), CFG)
    np.testing.assert_allclose(float(tot[2]), 1.0, rtol=1e-6)


def test_empty_graph_zero_totals():
    _, _, _, tot = estimate(*padded([]), CFG)
    assert float(tot[0]) == 0.0 and float(tot[1]) == 0.0
    assert int(tot[3]) == 0
