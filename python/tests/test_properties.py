"""Cross-cutting properties of the cost model the rust mirror relies on."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.cost_model import cost_pallas
from compile.kernels.ref import cost_ref

core_dim = st.sampled_from([4, 8, 16, 32, 64, 128, 256])


def one_op(kind, m, n, k, cfg, pad=128):
    kinds = np.full(pad, -1, np.int32)
    ms = np.ones(pad, np.int32)
    ns = np.ones(pad, np.int32)
    ks = np.ones(pad, np.int32)
    kinds[0], ms[0], ns[0], ks[0] = kind, m, n, k
    out = cost_pallas(
        jnp.asarray(kinds), jnp.asarray(ms), jnp.asarray(ns), jnp.asarray(ks),
        jnp.asarray(cfg, jnp.int32), block=pad,
    )
    return tuple(float(np.asarray(a)[0]) for a in out)


def test_determinism():
    a = one_op(0, 1234, 567, 89, [128, 64, 32])
    b = one_op(0, 1234, 567, 89, [128, 64, 32])
    assert a == b


@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 4096), n=st.integers(1, 4096), k=st.integers(1, 4096), c=core_dim)
def test_latency_monotone_in_k(m, n, k, c):
    """More reduction depth never makes a GEMM faster."""
    lat1, _, _ = one_op(0, m, n, k, [c, c, c])
    lat2, _, _ = one_op(0, m, n, k + 64, [c, c, c])
    assert lat2 >= lat1


@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 100_000), i=st.integers(1, 8), c=core_dim)
def test_vector_latency_monotone_in_intensity(m, i, c):
    lat1, _, _ = one_op(1, m, i, 1, [c, c, c])
    lat2, _, _ = one_op(1, m, i + 1, 1, [c, c, c])
    assert lat2 >= lat1


@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 4096), n=st.integers(1, 4096), k=st.integers(1, 4096), c=core_dim)
def test_energy_independent_of_core_dims(m, n, k, c):
    """Energy is event-based: MACs and bytes don't change with the array
    size (only latency and utilization do). The rust TDP model depends on
    this separation."""
    _, e_small, _ = one_op(0, m, n, k, [4, 4, 4])
    _, e_this, _ = one_op(0, m, n, k, [c, c, c])
    np.testing.assert_allclose(e_small, e_this, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 65_536),
    n=st.integers(1, 2048),
    k=st.integers(1, 2048),
    kind=st.integers(0, 2),
)
def test_block_boundary_invariance(m, n, k, kind):
    """The same op costs the same whether it lands in the first or the
    last row of a multi-block grid."""
    pad = 256
    block = 128  # 2 grid steps

    def at_row(row):
        kinds = np.full(pad, -1, np.int32)
        ms = np.ones(pad, np.int32)
        ns = np.ones(pad, np.int32)
        ks = np.ones(pad, np.int32)
        kinds[row], ms[row], ns[row], ks[row] = kind, m, n, k
        out = cost_pallas(
            jnp.asarray(kinds), jnp.asarray(ms), jnp.asarray(ns), jnp.asarray(ks),
            jnp.asarray([64, 64, 64], jnp.int32), block=block,
        )
        return tuple(float(np.asarray(a)[row]) for a in out)

    assert at_row(0) == at_row(pad - 1)


def test_extreme_config_corners_match_ref():
    rows = [(0, 1, 1, 1), (0, 2**20, 1, 1), (1, 2**24, 8, 1), (2, 4096, 4096, 4096)]
    pad = 128
    kinds = np.full(pad, -1, np.int32)
    ms = np.ones(pad, np.int32)
    ns = np.ones(pad, np.int32)
    ks = np.ones(pad, np.int32)
    for i, r in enumerate(rows):
        kinds[i], ms[i], ns[i], ks[i] = r
    for cfg in ([4, 4, 4], [256, 256, 256], [4, 256, 128]):
        args = tuple(jnp.asarray(a) for a in (kinds, ms, ns, ks))
        got = cost_pallas(*args, jnp.asarray(cfg, jnp.int32), block=pad)
        want = cost_ref(*args, jnp.asarray(cfg, jnp.int32))
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
