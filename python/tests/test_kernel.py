"""Pallas cost kernel vs pure-jnp oracle — the core correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.cost_model import cost_pallas
from compile.kernels.ref import BPC, cost_ref


def make_ops(rows, pad_to=128):
    """Build padded int32 op arrays from a list of (kind, m, n, k)."""
    kind = np.full(pad_to, -1, np.int32)
    m = np.ones(pad_to, np.int32)
    n = np.ones(pad_to, np.int32)
    k = np.ones(pad_to, np.int32)
    for i, (ki, mi, ni, kk) in enumerate(rows):
        kind[i], m[i], n[i], k[i] = ki, mi, ni, kk
    return (jnp.asarray(kind), jnp.asarray(m), jnp.asarray(n), jnp.asarray(k))


def run_both(rows, cfg, pad_to=128):
    ops = make_ops(rows, pad_to)
    cfg = jnp.asarray(cfg, jnp.int32)
    got = cost_pallas(*ops, cfg, block=pad_to if pad_to <= 512 else 512)
    want = cost_ref(*ops, cfg)
    return got, want


def assert_match(got, want):
    names = ["latency", "energy", "util"]
    for g, w, name in zip(got, want, names):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-6, atol=1e-6, err_msg=name
        )


# ------------------------------------------------------------ basic cases
def test_single_gemm_matches_ref():
    got, want = run_both([(0, 512, 512, 512)], [128, 128, 128])
    assert_match(got, want)


def test_vector_op_matches_ref():
    got, want = run_both([(1, 100_000, 4, 1)], [128, 128, 128])
    assert_match(got, want)


def test_fused_op_matches_ref():
    got, want = run_both([(2, 1024, 1024, 768)], [128, 128, 256])
    assert_match(got, want)


def test_padding_rows_are_zero():
    got, _ = run_both([(0, 64, 64, 64)], [32, 32, 32])
    lat = np.asarray(got[0])
    assert lat[0] > 0
    assert np.all(lat[1:] == 0.0)


def test_gemm_compute_formula():
    # m=n=k=256 on a 128x128 TC: tiles=4, compute=4*(256+128+128)=2048;
    # mem = 3*256*256*2 / BPC ~ 410.7 -> compute-bound.
    got, _ = run_both([(0, 256, 256, 256)], [128, 128, 128])
    assert np.isclose(float(got[0][0]), 4 * (256 + 128 + 128))


def test_memory_bound_vector_op():
    # Huge element count, intensity 1, wide core -> roofline hits HBM.
    mf = 1_000_000
    got, _ = run_both([(1, mf, 1, 1)], [128, 128, 256])
    expect_mem = 2 * mf * 2.0 / BPC
    assert np.isclose(float(got[0][0]), expect_mem, rtol=1e-5)


def test_full_utilization_when_divisible():
    got, _ = run_both([(0, 256, 256, 64)], [128, 128, 128])
    assert np.isclose(float(got[2][0]), 1.0)


def test_low_utilization_small_op():
    # 4x4 op on a 256x256 core occupies 16/65536 of the array.
    got, _ = run_both([(0, 4, 4, 64)], [256, 256, 256])
    assert np.isclose(float(got[2][0]), 16.0 / 65536.0, rtol=1e-5)


def test_larger_core_never_increases_compute_cycles_for_big_gemm():
    big = [(0, 4096, 4096, 4096)]
    lat128 = float(run_both(big, [128, 128, 128])[0][0][0])
    lat256 = float(run_both(big, [256, 256, 256])[0][0][0])
    assert lat256 <= lat128


def test_multi_block_grid():
    rows = [(i % 3, 64 * (i + 1), 32, 128) for i in range(64)]
    got, want = run_both(rows, [64, 64, 64], pad_to=1024)
    assert_match(got, want)


# ------------------------------------------------------- hypothesis sweeps
dims = st.integers(min_value=1, max_value=65_536)
small_dims = st.integers(min_value=1, max_value=4096)
core_dim = st.sampled_from([4, 8, 12, 16, 32, 60, 64, 100, 128, 240, 256])
kinds = st.integers(min_value=-1, max_value=2)


@settings(max_examples=60, deadline=None)
@given(
    rows=st.lists(st.tuples(kinds, dims, small_dims, small_dims), min_size=1, max_size=24),
    tc_x=core_dim,
    tc_y=core_dim,
    vc_w=core_dim,
)
def test_kernel_matches_ref_on_random_ops(rows, tc_x, tc_y, vc_w):
    got, want = run_both(rows, [tc_x, tc_y, vc_w])
    assert_match(got, want)


@settings(max_examples=30, deadline=None)
@given(m=dims, n=small_dims, k=small_dims, c=core_dim)
def test_costs_are_finite_positive(m, n, k, c):
    got, _ = run_both([(0, m, n, k), (1, m, n, 1), (2, m, n, k)], [c, c, c])
    lat, en, ut = (np.asarray(a)[:3] for a in got)
    assert np.all(np.isfinite(lat)) and np.all(lat > 0)
    assert np.all(np.isfinite(en)) and np.all(en > 0)
    assert np.all(ut > 0) and np.all(ut <= 1.0 + 1e-6)


@settings(max_examples=30, deadline=None)
@given(m=small_dims, n=small_dims, k=small_dims)
def test_fused_dominates_tensor_latency(m, n, k):
    """Fused latency >= plain tensor latency (adds an epilogue to the max)."""
    got, _ = run_both([(0, m, n, k), (2, m, n, k)], [128, 128, 128])
    lat = np.asarray(got[0])
    assert lat[1] >= lat[0] - 1e-3


def test_output_dtypes_are_f32():
    got, _ = run_both([(0, 64, 64, 64)], [32, 32, 32])
    for a in got:
        assert a.dtype == jnp.float32


def test_rejects_non_multiple_block():
    ops = make_ops([(0, 8, 8, 8)], pad_to=100)
    with pytest.raises(AssertionError):
        cost_pallas(*ops, jnp.asarray([8, 8, 8], jnp.int32), block=64)
