"""AOT lowering: HLO text is produced, parses back, and executes correctly.

The full rust-side PJRT round-trip (text -> HloModuleProto -> compile ->
execute) is covered by `rust/tests/pjrt_vs_native.rs`; here we check the
python half: the emitted text is structurally valid HLO that XLA's parser
accepts, and the lowered computation's numerics match the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.aot import lower_estimator
from compile.kernels.ref import cost_ref
from compile.model import N_OPS, estimate


def test_hlo_text_nonempty_and_has_entry():
    hlo = lower_estimator()
    assert "ENTRY" in hlo and "HloModule" in hlo
    assert len(hlo) > 1000


def test_hlo_text_parses_back():
    """xc's text parser (the one the rust xla crate binds) accepts it."""
    hlo = lower_estimator()
    mod = xc._xla.hlo_module_from_text(hlo)
    proto = mod.as_serialized_hlo_module_proto()
    assert len(proto) > 500
    comp = xc.XlaComputation(proto)
    prog = comp.program_shape()
    # 5 inputs: kind, m, n, k (i32[N_OPS]) + cfg (i32[3]).
    assert len(prog.parameter_shapes()) == 5
    assert prog.parameter_shapes()[0].dimensions() == (N_OPS,)
    assert prog.parameter_shapes()[4].dimensions() == (3,)


def test_hlo_signature_outputs_tuple_of_4():
    hlo = lower_estimator()
    mod = xc._xla.hlo_module_from_text(hlo)
    comp = xc.XlaComputation(mod.as_serialized_hlo_module_proto())
    result = comp.program_shape().result_shape()
    assert result.is_tuple() and len(result.tuple_shapes()) == 4


def test_jitted_estimator_matches_ref():
    """Numerics of the exact function that was lowered, vs the oracle."""
    kind = np.full(N_OPS, -1, np.int32)
    m = np.ones(N_OPS, np.int32)
    n = np.ones(N_OPS, np.int32)
    k = np.ones(N_OPS, np.int32)
    rows = [(0, 1024, 1024, 512), (1, 65536, 3, 1), (2, 768, 768, 768)]
    for i, r in enumerate(rows):
        kind[i], m[i], n[i], k[i] = r
    cfg = np.asarray([128, 128, 256], np.int32)

    args = tuple(jnp.asarray(a) for a in (kind, m, n, k))
    lat, en, ut, tot = jax.jit(estimate)(*args, jnp.asarray(cfg))
    rlat, ren, rut = cost_ref(*args, jnp.asarray(cfg))
    np.testing.assert_allclose(np.asarray(lat), np.asarray(rlat), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(en), np.asarray(ren), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ut), np.asarray(rut), rtol=1e-5)
    assert int(tot[3]) == len(rows)
