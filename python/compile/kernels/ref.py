"""Pure-jnp oracle for the WHAM operator cost model.

This file is the SINGLE SOURCE OF TRUTH for the cost-model semantics.  Three
implementations must agree with it:

  * the Pallas kernel (`cost_model.py`) — checked by pytest/hypothesis,
  * the AOT-lowered HLO artifact executed from rust via PJRT,
  * the native rust mirror (`rust/src/cost/native.rs`) — checked by the
    `pjrt_vs_native` integration test.

Semantics (DESIGN.md "Cost-model constants"): every operator of a training
graph is described by (kind, m, n, k):

  kind 0 (tensor) : GEMM-like op of m x n x k on a systolic tensor core of
                    tc_x x tc_y PEs.  Output-stationary tiling:
                    tiles = ceil(m/tc_x)*ceil(n/tc_y), each tile streams k
                    values plus a tc_x+tc_y pipeline fill.
  kind 1 (vector) : element-wise/reduction op over m elements with per-
                    element intensity n (cycles per element batch) on a
                    vc_w-lane vector core.
  kind 2 (fused)  : tensor op with an element-wise epilogue over its m*n
                    outputs, executed simultaneously on a TC+VC unit
                    (paper section 4): latency is the max of both parts.
  kind < 0        : padding — all outputs are zero.

Latency is a roofline: max(compute cycles, HBM cycles).  Energy is
event-based (MAC / SRAM byte / HBM byte / vector op).  Utilization is the
fraction of occupied PEs (or lanes) across the tiles the op touches.
"""

import jax.numpy as jnp

# ---------------------------------------------------------------- constants
BYTES = 2.0            # bf16 operand width
CLOCK_GHZ = 0.94       # TPUv2-like clock
HBM_GBPS = 900.0       # HBM bandwidth
BPC = HBM_GBPS / CLOCK_GHZ  # bytes per cycle = 957.4468...
E_MAC = 0.56           # pJ per MAC (bf16, ~22nm-class)
E_SRAM = 1.3           # pJ per SRAM byte
E_HBM = 7.0            # pJ per HBM byte
E_VEC = 0.31           # pJ per vector lane op


def _ceil_div_i32(a, b):
    """Exact integer ceil-div; inputs are int32 arrays/scalars."""
    return (a + b - 1) // b


def cost_ref(kind, m, n, k, cfg):
    """Reference cost model.

    Args:
      kind, m, n, k: int32 arrays of shape (N,).
      cfg: int32 array of shape (3,): [tc_x, tc_y, vc_w].

    Returns:
      (latency, energy, util): float32 arrays of shape (N,); latency in
      core cycles, energy in pJ, util in [0, 1].
    """
    kind = kind.astype(jnp.int32)
    m = m.astype(jnp.int32)
    n = n.astype(jnp.int32)
    k = k.astype(jnp.int32)
    tc_x, tc_y, vc_w = cfg[0], cfg[1], cfg[2]

    mf = m.astype(jnp.float32)
    nf = n.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    txf = tc_x.astype(jnp.float32)
    tyf = tc_y.astype(jnp.float32)
    vwf = vc_w.astype(jnp.float32)

    # ---------------- tensor part (kinds 0 and 2) -------------------------
    tiles_m = _ceil_div_i32(m, tc_x).astype(jnp.float32)
    tiles_n = _ceil_div_i32(n, tc_y).astype(jnp.float32)
    tiles = tiles_m * tiles_n
    t_compute = tiles * (kf + txf + tyf)
    t_bytes = (mf * kf + kf * nf + mf * nf) * BYTES
    t_mem = t_bytes / BPC
    macs = mf * nf * kf
    t_energy = macs * E_MAC + t_bytes * E_HBM + t_bytes * E_SRAM
    t_util = (mf * nf) / (tiles_m * txf * tiles_n * tyf)

    # ---------------- vector part (kind 1) --------------------------------
    v_groups = _ceil_div_i32(m, vc_w).astype(jnp.float32)
    v_compute = v_groups * nf  # n = per-element intensity
    v_bytes = 2.0 * mf * BYTES
    v_mem = v_bytes / BPC
    v_energy = mf * nf * E_VEC + v_bytes * E_HBM + v_bytes * E_SRAM
    v_util = mf / (v_groups * vwf)

    # ---------------- fused epilogue (kind 2) -----------------------------
    # Element-wise pass over the m*n tensor outputs, intensity 1; the
    # intermediate stays on-chip so no extra HBM traffic.  m*n can exceed
    # int32 for the largest GEMMs, so the group count is computed in f32
    # (exact enough: groups are < 2^24 for all modeled shapes).
    f_groups = jnp.ceil(mf * nf / vwf)
    f_vcompute = f_groups * 1.0
    f_energy = t_energy + mf * nf * E_VEC

    is_t = kind == 0
    is_v = kind == 1
    is_f = kind == 2
    valid = kind >= 0

    lat_t = jnp.maximum(t_compute, t_mem)
    lat_v = jnp.maximum(v_compute, v_mem)
    lat_f = jnp.maximum(jnp.maximum(t_compute, f_vcompute), t_mem)

    latency = jnp.where(is_t, lat_t, jnp.where(is_v, lat_v, jnp.where(is_f, lat_f, 0.0)))
    energy = jnp.where(is_t, t_energy, jnp.where(is_v, v_energy, jnp.where(is_f, f_energy, 0.0)))
    util = jnp.where(is_t | is_f, t_util, jnp.where(is_v, v_util, 0.0))

    zero = jnp.float32(0.0)
    latency = jnp.where(valid, latency, zero).astype(jnp.float32)
    energy = jnp.where(valid, energy, zero).astype(jnp.float32)
    util = jnp.where(valid, util, zero).astype(jnp.float32)
    return latency, energy, util
