"""Layer-1 Pallas kernel: batched WHAM operator cost model.

The hot-spot of WHAM's inner search loop is annotating every operator of a
training graph with (latency, energy, utilization) under a candidate
<TC-Dim, VC-Width>.  This kernel evaluates a whole operator table at once.

TPU mapping (DESIGN.md section Hardware-Adaptation): the operator table is
streamed HBM->VMEM in BLOCK-row tiles via BlockSpec; per-block work is pure
element-wise VPU arithmetic (no MXU), so the block size is chosen for VMEM
residency (512 ops x 4 int32 inputs + 3 f32 outputs = 14 KiB/block).

Must match `ref.py` exactly — see that file for the semantics.  Lowered
with interpret=True: real-TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BPC, BYTES, E_HBM, E_MAC, E_SRAM, E_VEC

BLOCK = 1024  # operator rows per VMEM-resident block (1024 halves grid steps vs 512; see EXPERIMENTS.md §Perf)


def _cost_kernel(cfg_ref, kind_ref, m_ref, n_ref, k_ref, lat_ref, en_ref, ut_ref):
    """One grid step: cost BLOCK operators against a single config."""
    kind = kind_ref[...]
    m = m_ref[...]
    n = n_ref[...]
    k = k_ref[...]
    tc_x = cfg_ref[0]
    tc_y = cfg_ref[1]
    vc_w = cfg_ref[2]

    mf = m.astype(jnp.float32)
    nf = n.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    txf = tc_x.astype(jnp.float32)
    tyf = tc_y.astype(jnp.float32)
    vwf = vc_w.astype(jnp.float32)

    # Tensor part (kinds 0, 2): output-stationary systolic tiling.
    tiles_m = ((m + tc_x - 1) // tc_x).astype(jnp.float32)
    tiles_n = ((n + tc_y - 1) // tc_y).astype(jnp.float32)
    tiles = tiles_m * tiles_n
    t_compute = tiles * (kf + txf + tyf)
    t_bytes = (mf * kf + kf * nf + mf * nf) * BYTES
    t_mem = t_bytes / BPC
    macs = mf * nf * kf
    t_energy = macs * E_MAC + t_bytes * E_HBM + t_bytes * E_SRAM
    t_util = (mf * nf) / (tiles_m * txf * tiles_n * tyf)

    # Vector part (kind 1): m elements at intensity n over vc_w lanes.
    v_groups = ((m + vc_w - 1) // vc_w).astype(jnp.float32)
    v_compute = v_groups * nf
    v_bytes = 2.0 * mf * BYTES
    v_mem = v_bytes / BPC
    v_energy = mf * nf * E_VEC + v_bytes * E_HBM + v_bytes * E_SRAM
    v_util = mf / (v_groups * vwf)

    # Fused epilogue (kind 2): element-wise over the m*n outputs, on-chip.
    f_groups = jnp.ceil(mf * nf / vwf)
    f_vcompute = f_groups * 1.0
    f_energy = t_energy + mf * nf * E_VEC

    is_t = kind == 0
    is_v = kind == 1
    is_f = kind == 2
    valid = kind >= 0

    lat_t = jnp.maximum(t_compute, t_mem)
    lat_v = jnp.maximum(v_compute, v_mem)
    lat_f = jnp.maximum(jnp.maximum(t_compute, f_vcompute), t_mem)

    latency = jnp.where(is_t, lat_t, jnp.where(is_v, lat_v, jnp.where(is_f, lat_f, 0.0)))
    energy = jnp.where(is_t, t_energy, jnp.where(is_v, v_energy, jnp.where(is_f, f_energy, 0.0)))
    util = jnp.where(is_t | is_f, t_util, jnp.where(is_v, v_util, 0.0))

    zero = jnp.float32(0.0)
    lat_ref[...] = jnp.where(valid, latency, zero).astype(jnp.float32)
    en_ref[...] = jnp.where(valid, energy, zero).astype(jnp.float32)
    ut_ref[...] = jnp.where(valid, util, zero).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def cost_pallas(kind, m, n, k, cfg, *, block=BLOCK):
    """Batched cost model as a Pallas call.

    Args mirror `ref.cost_ref`; N (= kind.shape[0]) must be a multiple of
    `block`.  Returns (latency, energy, util) float32 arrays of shape (N,).
    """
    n_ops = kind.shape[0]
    assert n_ops % block == 0, f"N={n_ops} must be a multiple of block={block}"
    grid = (n_ops // block,)
    row = pl.BlockSpec((block,), lambda i: (i,))
    whole_cfg = pl.BlockSpec((3,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n_ops,), jnp.float32)] * 3
    return pl.pallas_call(
        _cost_kernel,
        grid=grid,
        in_specs=[whole_cfg, row, row, row, row],
        out_specs=[row, row, row],
        out_shape=out_shape,
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(cfg, kind, m, n, k)
