"""AOT compile path: lower the Layer-2 estimator to HLO text.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(the version the published `xla` rust crate binds) rejects with
`proto.id() <= INT_MAX`.  The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/gen_hlo.py).

Run once via `make artifacts`:
    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import N_OPS, estimate


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_estimator() -> str:
    i32v = jax.ShapeDtypeStruct((N_OPS,), jnp.int32)
    i32c = jax.ShapeDtypeStruct((3,), jnp.int32)
    lowered = jax.jit(estimate).lower(i32v, i32v, i32v, i32v, i32c)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    hlo = lower_estimator()
    hlo_path = os.path.join(args.out_dir, "cost_model.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)

    # Tiny metadata sidecar so the rust runtime can sanity-check its
    # assumptions about the artifact without parsing HLO.
    meta_path = os.path.join(args.out_dir, "cost_model.meta")
    with open(meta_path, "w") as f:
        f.write(f"n_ops={N_OPS}\n")
        f.write("inputs=kind:i32[N],m:i32[N],n:i32[N],k:i32[N],cfg:i32[3]\n")
        f.write("outputs=latency:f32[N],energy:f32[N],util:f32[N],totals:f32[4]\n")

    print(f"wrote {len(hlo)} chars to {hlo_path} (N_OPS={N_OPS})")


if __name__ == "__main__":
    main()
