"""Layer-2 JAX model: the full WHAM architecture estimator.

Wraps the Layer-1 Pallas cost kernel into the estimator the rust
coordinator calls: per-operator costs plus masked whole-graph aggregates.
This function is AOT-lowered once (aot.py) to artifacts/cost_model.hlo.txt
and executed from rust via PJRT — Python is never on the search path.

Input contract (fixed shapes, see aot.py):
  kind, m, n, k : int32[N_OPS]   operator table (padding rows: kind = -1)
  cfg           : int32[3]       [tc_x, tc_y, vc_w]

Output tuple:
  latency : f32[N_OPS]  cycles per operator
  energy  : f32[N_OPS]  pJ per operator
  util    : f32[N_OPS]  core utilization in [0,1]
  totals  : f32[4]      [sum(latency), sum(energy), mean(util over valid),
                         valid-op count]
"""

import jax.numpy as jnp

from .kernels.cost_model import cost_pallas

# Fixed operator-table height of the AOT artifact.  Graphs larger than
# this are chunked by the rust caller (rust/src/cost/xla_rt.rs).
N_OPS = 4096


def estimate(kind, m, n, k, cfg):
    """Per-op costs + aggregates for one candidate <TC-Dim, VC-Width>."""
    latency, energy, util = cost_pallas(kind, m, n, k, cfg)
    valid = (kind >= 0).astype(jnp.float32)
    count = jnp.sum(valid)
    totals = jnp.stack(
        [
            jnp.sum(latency),
            jnp.sum(energy),
            jnp.sum(util * valid) / jnp.maximum(count, 1.0),
            count,
        ]
    ).astype(jnp.float32)
    return latency, energy, util, totals
