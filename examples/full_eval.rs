//! End-to-end driver: runs the FULL system on the real workload zoo and
//! reports the paper's headline metrics. This is the e2e validation run
//! recorded in EXPERIMENTS.md:
//!
//! 1. builds all 11 Table-4 training graphs (fwd + mirrored bwd + Adam);
//! 2. verifies the three-layer stack (PJRT artifact vs native mirror);
//! 3. single-accelerator: WHAM-individual (parallel coordinator) +
//!    WHAM-common over the 8 workloads vs TPUv2 / NVDLA;
//! 4. distributed: depth-32 GPipe global search for OPT-1.3B and GPT2-XL
//!    plus the GPT3 TMP=8/PP=8 point, vs a TPUv2 pipeline.
//!
//! Run with: `make artifacts && cargo run --release --example full_eval`

use wham::arch::presets;
use wham::coordinator::{make_backend, run_parallel, BackendChoice, SearchJob};
use wham::distributed::global_search::{global_search, GlobalOptions};
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::pipeline::simulate;
use wham::distributed::Scheme;
use wham::graph::autodiff::Optimizer;
use wham::report::geomean;
use wham::search::engine::{evaluate_design, SearchOptions};
use wham::util::table::Table;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    println!("== WHAM full evaluation (end-to-end driver) ==\n");

    // ---- 1. workload zoo --------------------------------------------------
    println!("[1/4] building the Table-4 workload zoo");
    for m in wham::models::MODELS {
        let g = wham::models::training(m.name, Optimizer::Adam).unwrap();
        wham::graph::validate::validate(&g)
            .unwrap_or_else(|e| panic!("{}: {e}", m.name));
        println!("  {:<14} {:>6} ops  {:>8} edges", m.name, g.len(), g.num_edges());
    }

    // ---- 2. three-layer stack check ---------------------------------------
    println!("\n[2/4] three-layer stack: PJRT artifact vs native mirror");
    let g = wham::models::training("bert-base", Optimizer::Adam).unwrap();
    let mut native = make_backend(BackendChoice::Native)?;
    let en = evaluate_design(&g, 4, &presets::tpuv2(), native.as_mut());
    match make_backend(BackendChoice::Pjrt) {
        Ok(mut pjrt) => {
            let ep = evaluate_design(&g, 4, &presets::tpuv2(), pjrt.as_mut());
            let rel = (en.seconds - ep.seconds).abs() / en.seconds;
            println!("  bert-base iter: native {:.4}s, pjrt {:.4}s (rel {rel:.2e})", en.seconds, ep.seconds);
            assert!(rel < 1e-3, "backends disagree");
        }
        Err(e) => println!("  (PJRT unavailable: {e}; native mirror only)"),
    }

    // ---- 3. single-accelerator searches ------------------------------------
    println!("\n[3/4] single-accelerator: WHAM-individual + WHAM-common vs TPUv2/NVDLA");
    let names = wham::models::single_acc_models();
    let jobs: Vec<SearchJob> = names
        .iter()
        .map(|n| SearchJob {
            name: n.to_string(),
            graph: wham::models::training(n, Optimizer::Adam).unwrap(),
            batch: wham::models::info(n).unwrap().batch,
            opts: SearchOptions::default(),
        })
        .collect();
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let individual = run_parallel(jobs, BackendChoice::Auto, workers);

    let mut backend = make_backend(BackendChoice::Auto)?;
    let graphs: Vec<(String, wham::graph::OperatorGraph, u64)> = names
        .iter()
        .map(|n| {
            (
                n.to_string(),
                wham::models::training(n, Optimizer::Adam).unwrap(),
                wham::models::info(n).unwrap().batch,
            )
        })
        .collect();
    let workloads: Vec<wham::search::common::Workload> = graphs
        .iter()
        .map(|(n, g, b)| wham::search::common::Workload {
            name: n.clone(),
            graph: g,
            batch: *b,
            min_throughput: 0.0,
            weight: 1.0,
        })
        .collect();
    let common =
        wham::search::common::search_common(&workloads, SearchOptions::default(), backend.as_mut());
    println!("  WHAM-common config: {}", common.best.0);

    let mut t = Table::new(["model", "wham-individual", "thpt", "vs tpuv2", "vs nvdla", "common vs tpuv2"]);
    let mut ind_vs_tpu = Vec::new();
    let mut com_vs_tpu = Vec::new();
    let mut com_vs_nvdla = Vec::new();
    for ((name, graph, batch), (jname, r)) in graphs.iter().zip(&individual) {
        assert_eq!(name, jname);
        let r = r.as_ref().map_err(|e| anyhow::anyhow!("search for {jname} failed: {e}"))?;
        let tpu = evaluate_design(graph, *batch, &presets::tpuv2(), backend.as_mut());
        let nvdla = evaluate_design(graph, *batch, &presets::nvdla_scaled(), backend.as_mut());
        let com = evaluate_design(graph, *batch, &common.best.0, backend.as_mut());
        ind_vs_tpu.push(r.best.eval.throughput / tpu.throughput);
        com_vs_tpu.push(com.throughput / tpu.throughput);
        com_vs_nvdla.push(com.throughput / nvdla.throughput);
        t.row([
            name.clone(),
            r.best.config.display(),
            format!("{:.2}/s", r.best.eval.throughput),
            format!("{:.3}x", r.best.eval.throughput / tpu.throughput),
            format!("{:.3}x", r.best.eval.throughput / nvdla.throughput),
            format!("{:.3}x", com.throughput / tpu.throughput),
        ]);
    }
    print!("{t}");
    println!(
        "  geomean: individual {:.3}x TPUv2 (paper 1.15x) | common {:.3}x TPUv2 (paper 1.12x), {:.3}x NVDLA (paper 2x)",
        geomean(ind_vs_tpu.iter().copied()),
        geomean(com_vs_tpu.iter().copied()),
        geomean(com_vs_nvdla.iter().copied())
    );

    // ---- 4. distributed training -------------------------------------------
    println!("\n[4/4] distributed: depth-32 GPipe (OPT-1.3B, GPT2-XL) + GPT3 TMP8/PP8");
    let net = Network::default();
    let parts = vec![
        partition_transformer("opt-1.3b", &wham::models::transformer_cfg("opt-1.3b").unwrap(), 32, 1, Optimizer::Adam),
        partition_transformer("gpt2-xl", &wham::models::transformer_cfg("gpt2-xl").unwrap(), 32, 1, Optimizer::Adam),
        partition_transformer("gpt3", &wham::models::transformer_cfg("gpt3").unwrap(), 8, 8, Optimizer::Adam),
    ];
    let r = global_search(&parts, &GlobalOptions::default(), &net, backend.as_mut());
    let mut t2 = Table::new(["model", "family", "thpt", "vs tpuv2 pipeline"]);
    for (i, part) in parts.iter().enumerate() {
        let cfgs = vec![presets::tpuv2(); part.stages.len()];
        let tpu = simulate(part, &cfgs, Scheme::GPipe, &net, backend.as_mut());
        for (fam, m) in [
            ("common", &r.common.1[i]),
            ("individual", &r.individual[i]),
            ("mosaic", &r.mosaic[i]),
        ] {
            t2.row([
                part.name.clone(),
                fam.to_string(),
                format!("{:.3}/s", m.eval.throughput),
                format!("{:.3}x", m.eval.throughput / tpu.throughput),
            ]);
        }
    }
    print!("{t2}");
    println!(
        "  (paper: common 1.17x, individual 1.22x, mosaic 1.23x over TPUv2 at depth 32)"
    );

    println!("\nfull_eval completed in {:?}", t0.elapsed());
    Ok(())
}
