//! Distributed search walkthrough (paper section 5): partition GPT2-XL
//! into a depth-32 GPipe pipeline, run the per-stage top-k local searches
//! plus the global pruner, and compare the three WHAM families against a
//! TPUv2 pipeline.

use wham::arch::presets;
use wham::coordinator::{make_backend, BackendChoice};
use wham::distributed::global_search::{global_search, GlobalOptions};
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::pipeline::simulate;
use wham::distributed::Scheme;
use wham::graph::autodiff::Optimizer;

fn main() -> anyhow::Result<()> {
    let mut backend = make_backend(BackendChoice::Auto)?;
    let net = Network::default();

    let cfg = wham::models::transformer_cfg("gpt2-xl").unwrap();
    let part = partition_transformer("gpt2-xl", &cfg, 32, 1, Optimizer::Adam);
    println!(
        "gpt2-xl: {} stages, microbatch {}, {} microbatches/iter",
        part.stages.len(),
        part.micro_batch,
        part.num_micro
    );
    for s in part.stages.iter().take(3) {
        println!(
            "  stage {}: layers {:?}, {} ops, state {}, stash/mb {}",
            s.index,
            s.layers,
            s.graph.len(),
            wham::util::human_bytes(s.state_bytes),
            wham::util::human_bytes(s.stash_bytes)
        );
    }
    println!("  ... (all {} stages fit 16 GiB HBM under GPipe: {})",
        part.stages.len(),
        part.stages.iter().all(|s| s.fits_hbm(Scheme::GPipe, part.num_micro, 32)));

    // TPUv2 pipeline baseline.
    let cfgs = vec![presets::tpuv2(); part.stages.len()];
    let tpu = simulate(&part, &cfgs, Scheme::GPipe, &net, backend.as_mut());
    println!("\nTPUv2 pipeline: {:.3} samples/s (iter {:.1} ms, bottleneck stage {})",
        tpu.throughput, tpu.iter_seconds * 1e3, tpu.bottleneck);

    // Global search: per-stage top-k + area-ordered global pruner.
    let r = global_search(
        std::slice::from_ref(&part),
        &GlobalOptions::default(),
        &net,
        backend.as_mut(),
    );
    println!(
        "global search: {} local searches (stage dedup), pool {}, {} evaluated, {:?}",
        r.local_searches, r.candidate_pool, r.candidates_evaluated, r.wall
    );
    for (fam, m) in [
        ("common", &r.common.1[0]),
        ("individual", &r.individual[0]),
        ("mosaic", &r.mosaic[0]),
    ] {
        println!(
            "  WHAM-{fam:<10} {:>9.3} samples/s  ({:.3}x TPUv2)  perf/TDP {:.5}",
            m.eval.throughput,
            m.eval.throughput / tpu.throughput,
            m.eval.perf_per_tdp
        );
    }
    Ok(())
}
