//! Sweep bert-base across 8/16/32-device cluster topologies through
//! the typed API — the cluster-level mirror of `api_session.rs`.
//!
//! ```bash
//! cargo run --release --example cluster_sweep
//! ```
//!
//! The 8-device flat cluster also mines hardware for its best strategy
//! (the larger topologies screen on the TPUv2 reference to keep the
//! example quick); the session's shared design database means the
//! mining cost is paid once even across repeated sweeps.

use std::sync::Arc;

use wham::api::{ClusterRequest, Session};
use wham::coordinator::BackendChoice;
use wham::service::cache::DesignDb;

fn main() -> anyhow::Result<()> {
    let db = Arc::new(DesignDb::in_memory());
    let mut session = Session::new(BackendChoice::Auto)?.with_db(Arc::clone(&db));
    println!("session backend: {}", session.backend_name());

    for (devices, topology, mine) in
        [(8u64, "flat", 1u64), (16, "fat-tree", 0), (32, "nvlink-island", 0)]
    {
        let req = ClusterRequest::new("bert-base")
            .devices(devices)
            .topology(topology)
            .mine_top(mine)
            .top_k(3)
            .hysteresis(0);
        let reply = session.cluster(&req)?;
        let top = &reply.ranked[0];
        let base = &reply.baseline;
        println!(
            "\n{} devices ({topology}): {} strategies screened, {} mined",
            devices, reply.candidates, reply.mined
        );
        println!(
            "  best: pp={} tp={} dp={} {}{} on {}{} -> {:.2} samples/s ({:.1}% bubble)",
            top.pp,
            top.tp,
            top.dp,
            top.schedule,
            if top.chunks > 1 { format!("x{}", top.chunks) } else { String::new() },
            top.config.display(),
            if top.mined { " (mined)" } else { "" },
            top.throughput,
            top.bubble_fraction * 100.0,
        );
        println!(
            "  fixed baseline pp={} tp=1 ({}): {:.2} samples/s -> best is {:.2}x",
            base.pp,
            base.schedule,
            base.throughput,
            top.throughput / base.throughput.max(1e-12),
        );
        // Under the throughput metric, a feasible baseline is in the
        // ranked set, so the top entry can never fall below it.
        if base.fits_hbm {
            assert!(top.throughput >= base.throughput, "ranked report must beat the baseline");
        }
    }
    println!("\n{} design points accumulated in the shared db", db.len());
    Ok(())
}
