//! Quickstart: mine an accelerator for one workload in ~20 lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use wham::arch::presets;
use wham::coordinator::{make_backend, BackendChoice};
use wham::graph::autodiff::Optimizer;
use wham::search::engine::{evaluate_design, SearchOptions, WhamSearch};

fn main() -> anyhow::Result<()> {
    // 1. Pick a workload from the Table-4 zoo and build its full training
    //    graph (forward + mirrored backward + optimizer updates).
    let graph = wham::models::training("resnet18", Optimizer::Adam).expect("model registered");
    let batch = wham::models::info("resnet18").unwrap().batch;
    println!("resnet18 training graph: {} ops, {} edges", graph.len(), graph.num_edges());

    // 2. Cost backend: the AOT-compiled Pallas/JAX estimator via PJRT when
    //    artifacts are built, the bit-compatible native mirror otherwise.
    let mut backend = make_backend(BackendChoice::Auto)?;
    println!("cost backend: {}", backend.name());

    // 3. Run WHAM's search: dimension pruning (Algorithm 2) around the
    //    Mirror Conflict Resolution core-count heuristic (Algorithm 1).
    let result = WhamSearch::new(&graph, batch, SearchOptions::default()).run(backend.as_mut());
    println!(
        "best design {} — {:.1} samples/s ({} dims explored in {:?})",
        result.best.config,
        result.best.eval.throughput,
        result.dims_evaluated,
        result.wall
    );

    // 4. Compare against the hand-optimized baselines.
    for (name, cfg) in [("TPUv2", presets::tpuv2()), ("NVDLA", presets::nvdla_scaled())] {
        let e = evaluate_design(&graph, batch, &cfg, backend.as_mut());
        println!(
            "  vs {name:<6} {}: {:.3}x throughput",
            cfg,
            result.best.eval.throughput / e.throughput
        );
    }
    Ok(())
}
