//! Library callers drive the mining core through `wham::api` — the same
//! typed request/plan/reply layer behind the CLI and the HTTP service.
//!
//! ```bash
//! cargo run --release --example api_session
//! ```

use std::sync::Arc;

use wham::api::{EvaluateRequest, SearchRequest, Session, ToJson};
use wham::arch::presets;
use wham::coordinator::BackendChoice;
use wham::service::cache::DesignDb;

fn main() -> anyhow::Result<()> {
    // A session owns the cost backend; attaching a design database makes
    // repeat searches free (the `wham serve` warm path, in-process).
    let db = Arc::new(DesignDb::in_memory());
    let mut session = Session::new(BackendChoice::Auto)?.with_db(Arc::clone(&db));
    println!("session backend: {}", session.backend_name());

    // 1. Typed request via the builder; `validate()` + execution happen
    //    behind `Session::search`.
    let request = SearchRequest::new("bert-base").top_k(3);
    let reply = session.search(&request)?;
    println!(
        "cold search: best {} score={:.4} ({} dims, {} scheduler evals, {:.0}ms)",
        reply.best.config.display(),
        reply.best.score,
        reply.dims_evaluated,
        reply.scheduler_evals,
        reply.wall_ms,
    );
    println!("  vs TPUv2 {:.3}x, vs NVDLA {:.3}x", reply.vs_tpuv2, reply.vs_nvdla);

    // 2. Same request again: every point is served from the database.
    let warm = session.search(&request)?;
    println!(
        "warm search: {} scheduler evals, {} cache hits ({} designs in the db)",
        warm.scheduler_evals,
        warm.cache_hits,
        db.len(),
    );
    assert_eq!(warm.scheduler_evals, 0, "warm search must not run the scheduler");

    // 3. Evaluate a fixed baseline design on the same workload.
    let eval = session.evaluate(&EvaluateRequest::new("bert-base", presets::tpuv2()))?;
    println!(
        "TPUv2 on bert-base: {:.3} samples/s (fingerprint {})",
        eval.eval.throughput, eval.fingerprint,
    );

    // 4. The wire form: these bytes are exactly what `wham client` POSTs
    //    and what the service parses — one codec on both ends.
    println!("wire request: {}", request.to_json());
    Ok(())
}
