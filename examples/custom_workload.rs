//! Mine an accelerator for a workload that exists only as data — no
//! Rust edit, no recompile.
//!
//! `examples/workloads/llama-decoder.json` describes a llama-style
//! decoder (RMSNorm-ish pre-norms, rotary eltwise on Q/K, SwiGLU MLP,
//! untied LM head) that is *not* in the paper's Table-4 zoo. This
//! example loads it through the workload-dir layer of the registry and
//! searches it exactly like a builtin:
//!
//! ```bash
//! cargo run --release --example custom_workload
//! # equivalently, from the CLI:
//! #   wham search --model llama-decoder --workload-dir examples/workloads
//! ```

use wham::api::{SearchRequest, Session};
use wham::coordinator::BackendChoice;

fn main() -> anyhow::Result<()> {
    // 1. Register every spec in the directory (the CLI's --workload-dir
    //    / WHAM_WORKLOAD_DIR do exactly this).
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/workloads");
    let names = wham::workload::add_dir(dir)?;
    println!("registered from {dir}: {names:?}");

    // 2. The spec lowers through the same shape-inference pass the
    //    builtins use; lint-level stats come back with the registration.
    let report = wham::workload::lint(&std::fs::read_to_string(
        format!("{dir}/llama-decoder.json"),
    )?)?;
    println!(
        "llama-decoder: {} forward ops -> {} training ops, fingerprint {}",
        report.forward_ops, report.training_ops, report.fingerprint
    );

    // 3. Search it by name, like any Table-4 workload.
    let mut session = Session::new(BackendChoice::Auto)?;
    let reply = session.search(&SearchRequest::new("llama-decoder"))?;
    println!(
        "best design {} — {:.1} samples/s ({:.3}x TPUv2, {} dims explored)",
        reply.best.config.display(),
        reply.best.eval.throughput,
        reply.vs_tpuv2,
        reply.dims_evaluated,
    );

    // 4. Its `transformer` section also opts it into the distributed
    //    paths (`wham global` / `wham partition`).
    let cfg = wham::workload::transformer_cfg("llama-decoder").expect("transformer section");
    println!(
        "pipeline-eligible: {} layers, hidden {}, seq {} (partition like a builtin LLM)",
        cfg.layers, cfg.hidden, cfg.seq
    );
    Ok(())
}
