//! WHAM-common (paper section 4.6): mine ONE accelerator serving a whole
//! workload set — here the five vision models — and compare it with the
//! hand-optimized designs on every workload.

use wham::arch::presets;
use wham::coordinator::{make_backend, BackendChoice};
use wham::graph::autodiff::Optimizer;
use wham::report::{geomean, speedup_table};
use wham::search::common::{search_common, Workload};
use wham::search::engine::{evaluate_design, SearchOptions};

fn main() -> anyhow::Result<()> {
    let names = ["mobilenet_v3", "resnet18", "inception_v3", "resnext101", "vgg16"];
    let mut backend = make_backend(BackendChoice::Auto)?;

    let graphs: Vec<(String, wham::graph::OperatorGraph, u64)> = names
        .iter()
        .map(|n| {
            (
                n.to_string(),
                wham::models::training(n, Optimizer::Adam).unwrap(),
                wham::models::info(n).unwrap().batch,
            )
        })
        .collect();
    let workloads: Vec<Workload> = graphs
        .iter()
        .map(|(n, g, b)| Workload {
            name: n.clone(),
            graph: g,
            batch: *b,
            min_throughput: 0.0,
            weight: 1.0,
        })
        .collect();

    let r = search_common(&workloads, SearchOptions::default(), backend.as_mut());
    println!(
        "WHAM-common over {} vision workloads: {} (weighted score {:.3}, {} dims, {:?})",
        names.len(),
        r.best.0,
        r.best.1,
        r.dims_evaluated,
        r.wall
    );

    let mut rows = Vec::new();
    let mut vs_tpu = Vec::new();
    let mut vs_nvdla = Vec::new();
    for (n, g, b) in &graphs {
        let common = evaluate_design(g, *b, &r.best.0, backend.as_mut());
        let tpu = evaluate_design(g, *b, &presets::tpuv2(), backend.as_mut());
        let nvdla = evaluate_design(g, *b, &presets::nvdla_scaled(), backend.as_mut());
        vs_tpu.push(common.throughput / tpu.throughput);
        vs_nvdla.push(common.throughput / nvdla.throughput);
        rows.push((
            n.clone(),
            vec![common.throughput, common.throughput / tpu.throughput, common.throughput / nvdla.throughput],
        ));
    }
    print!("{}", speedup_table(&["thpt (samples/s)", "vs tpuv2", "vs nvdla"], &rows));
    println!(
        "geomean: {:.3}x over TPUv2, {:.3}x over NVDLA",
        geomean(vs_tpu.iter().copied()),
        geomean(vs_nvdla.iter().copied())
    );
    Ok(())
}
