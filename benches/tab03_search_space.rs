//! Table 3 — search-space sizes (log10 candidate counts) for exhaustive /
//! ILP / heuristics, with and without the configuration pruner.
//!
//! Paper rows for reference:
//!   MobileNet_v3: 38 / 24 / 14 / 21 / 10
//!   Inception_v3: 39 / 25 / 14 / 22 / 12
//!   ResNeXt-101 : 40 / 26 / 15 / 23 / 13
//!   BERT-Large  : 40 / 26 / 16 / 23 / 13
//! Absolute magnitudes depend on the accounting convention (ours is
//! documented in search::space); the orderings and the ~10-orders-of-
//! magnitude pruner reduction are the claims under test.

use wham::coordinator::{make_backend, BackendChoice};
use wham::cost::annotate::AnnotatedGraph;
use wham::cost::Dims;
use wham::graph::autodiff::Optimizer;
use wham::search::engine::{SearchOptions, WhamSearch};
use wham::search::space::space_sizes;
use wham::util::bench::banner;
use wham::util::table::Table;

fn main() {
    banner("tab03", "search-space sizes (log10), +paper reference");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let paper: &[(&str, [f64; 5])] = &[
        ("mobilenet_v3", [38.0, 24.0, 14.0, 21.0, 10.0]),
        ("inception_v3", [39.0, 25.0, 14.0, 22.0, 12.0]),
        ("resnext101", [40.0, 26.0, 15.0, 23.0, 13.0]),
        ("bert-large", [40.0, 26.0, 16.0, 23.0, 13.0]),
    ];
    let mut t = Table::new([
        "model",
        "exhaustive",
        "ILP unpruned",
        "ILP pruned",
        "heur unpruned",
        "heur pruned",
        "paper (e/iu/ip/hu/hp)",
    ]);
    for (name, pref) in paper {
        let graph = wham::models::training(name, Optimizer::Adam).unwrap();
        let batch = wham::models::info(name).unwrap().batch;
        // Actual pruner footprint from a real search run.
        let r = WhamSearch::new(&graph, batch, SearchOptions::default()).run(backend.as_mut());
        let ann =
            AnnotatedGraph::new(&graph, Dims { tc_x: 128, tc_y: 128, vc_w: 128 }, backend.as_mut());
        let s = space_sizes(&ann, r.dims_evaluated);
        // Orderings under test.
        assert!(s.exhaustive > s.ilp_unpruned);
        assert!(s.ilp_unpruned > s.ilp_pruned);
        assert!(s.heur_unpruned > s.heur_pruned);
        assert!(s.ilp_unpruned > s.heur_unpruned);
        assert!(
            s.heur_unpruned - s.heur_pruned >= 0.4,
            "pruner must cut a visible fraction of the space"
        );
        t.row([
            name.to_string(),
            format!("10^{:.0}", s.exhaustive),
            format!("10^{:.0}", s.ilp_unpruned),
            format!("10^{:.0}", s.ilp_pruned),
            format!("10^{:.0}", s.heur_unpruned),
            format!("10^{:.0}", s.heur_pruned),
            format!(
                "10^{:.0}/{:.0}/{:.0}/{:.0}/{:.0}",
                pref[0], pref[1], pref[2], pref[3], pref[4]
            ),
        ]);
    }
    print!("{t}");
    println!("\ntab03 OK");
}
