//! Cluster-subsystem benchmarks: discrete-event simulator throughput
//! (events/second per schedule) and strategy-sweep wall time.
//!
//! Besides the human-readable report, writes `BENCH_cluster.json` so CI
//! can archive the trajectory alongside `BENCH_hotpath.json` (`--smoke`
//! runs a fast variant with the same schema; `--out PATH` redirects the
//! artifact).

use wham::api::progress::NullSink;
use wham::arch::presets;
use wham::cluster::{
    events_total, simulate_events, sweep, Placement, SimSchedule, SweepOptions, Topology,
};
use wham::cost::native::NativeCost;
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::pipeline::{stage_times, StageTimes};
use wham::graph::autodiff::Optimizer;
use wham::search::engine::{NoSharedCache, SearchOptions};
use wham::util::bench::{banner, bench, time_once, BenchStats};
use wham::util::json::{arr, Obj};

fn phase_json(s: &BenchStats) -> String {
    Obj::new()
        .str("name", &s.name)
        .u64("iters", s.iters as u64)
        .u64("median_ns", s.median.as_nanos() as u64)
        .u64("mean_ns", s.mean.as_nanos() as u64)
        .u64("min_ns", s.min.as_nanos() as u64)
        .finish()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke") || std::env::var("BENCH_SMOKE").is_ok();
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "BENCH_cluster.json".to_string());
    let (warm, iters) = if smoke { (1, 3) } else { (2, 20) };

    banner("cluster", "event-sim events/sec + strategy-sweep wall time");

    // ---- event-sim throughput --------------------------------------
    // An 8-rank pipeline driven far past its natural microbatch count
    // so the event queue, not setup, dominates.
    let mut cfg = wham::models::transformer::gpt2_xl();
    cfg.layers = 8;
    let mut part = partition_transformer("mini-gpt2", &cfg, 8, 1, Optimizer::SgdMomentum);
    part.num_micro = if smoke { 64 } else { 256 };
    let net = Network::default();
    let times: Vec<StageTimes> = part
        .stages
        .iter()
        .map(|s| stage_times(s, &presets::tpuv2(), part.tmp, &net, &mut NativeCost))
        .collect();
    let topo = Topology::preset("nvlink-island", 8).unwrap();
    println!(
        "workload: 8-stage mini gpt2-xl pipeline, {} microbatches, nvlink-island topology",
        part.num_micro
    );

    let mut phases: Vec<BenchStats> = Vec::new();
    let mut rates: Vec<(String, f64)> = Vec::new();
    for schedule in [
        SimSchedule::GPipe,
        SimSchedule::OneF1B,
        SimSchedule::Interleaved1F1B { devices: 4 },
    ] {
        // Interleaved folds the 8 virtual stages onto 4 ranks; the
        // plain schedules place one stage per rank.
        let ranks = match schedule {
            SimSchedule::Interleaved1F1B { devices } => devices,
            _ => part.stages.len() as u64,
        };
        let placement = Placement::linear(&topo, ranks, 1).unwrap();
        let run = || {
            simulate_events(&part, &times, schedule, &topo, &placement)
                .expect("valid simulation shape")
        };
        let events = run().events;
        let stats = bench(&format!("event_sim/{}", schedule.name()), warm, iters, || {
            std::hint::black_box(run());
        });
        let rate = events as f64 / stats.median.as_secs_f64().max(1e-12);
        println!("{stats}");
        println!("  {} events/iteration -> {:.0} events/sec", events, rate);
        rates.push((schedule.name().to_string(), rate));
        phases.push(stats);
    }

    // ---- strategy-sweep wall time ----------------------------------
    let tiny = wham::models::transformer::TransformerCfg {
        layers: 4,
        hidden: 128,
        heads: 4,
        seq: 64,
        batch: 8,
        vocab: 1000,
        ffn_mult: 4,
        tmp: 1,
    };
    let quick = SearchOptions { top_k: 2, hysteresis: 0, ..Default::default() };
    let opts = SweepOptions {
        devices: if smoke { 4 } else { 8 },
        mine_top: if smoke { 0 } else { 1 },
        local: quick,
        ..Default::default()
    };
    let (report, sweep_wall) = time_once(|| {
        sweep("tiny", &tiny, &opts, &mut NativeCost, &NoSharedCache, &mut NullSink).unwrap()
    });
    println!(
        "strategy sweep: {} candidates, {} mined, wall {:.1}ms (devices={})",
        report.candidates,
        report.mined,
        sweep_wall.as_secs_f64() * 1e3,
        opts.devices,
    );
    if report.baseline.fits_hbm {
        assert!(report.ranked[0].throughput >= report.baseline.throughput);
    }

    let json = Obj::new()
        .str("bench", "cluster")
        .bool("smoke", smoke)
        .str("workload", "mini-gpt2")
        .u64("microbatches", part.num_micro)
        .raw(
            "event_sim",
            &arr(rates.iter().map(|(name, rate)| {
                Obj::new().str("schedule", name).f64("events_per_sec", *rate).finish()
            })),
        )
        .raw(
            "sweep",
            &Obj::new()
                .u64("devices", opts.devices)
                .u64("candidates", report.candidates as u64)
                .u64("mined", report.mined as u64)
                .f64("wall_ms", sweep_wall.as_secs_f64() * 1e3)
                .f64("best_throughput", report.ranked[0].throughput)
                .f64("baseline_throughput", report.baseline.throughput)
                .finish(),
        )
        .raw("phases", &arr(phases.iter().map(phase_json)))
        .raw(
            "process",
            &Obj::new().u64("cluster_sim_events_total", events_total()).finish(),
        )
        // Full registry snapshot (every `wham_*` counter this process
        // touched) so counter trajectories ride the bench artifact.
        .raw("metrics", &wham::telemetry::snapshot_json())
        .finish();
    std::fs::write(&out_path, &json).expect("writing bench artifact");
    println!("\nwrote {out_path}");
    println!("cluster OK");
}
