//! Figure 8 — convergence-time comparison: WHAM (heuristics, and ILP
//! where tractable) vs ConfuciuX+ and Spotlight+ at the paper's
//! 500-iteration budget, wall-clock on this machine.
//!
//! Paper claims under test: WHAM converges on average 174x faster than
//! ConfuciuX+ and 31x faster than Spotlight+; the ILP does not converge
//! on language/translation models (reported N/A in the paper; our B&B
//! reports `optimal=false` the same way).

use wham::baselines::{confuciux, spotlight};
use wham::coordinator::{make_backend, BackendChoice};
use wham::graph::autodiff::Optimizer;
use wham::report::geomean;
use wham::search::engine::{SearchOptions, WhamSearch};
use wham::util::bench::banner;
use wham::util::table::Table;

fn main() {
    banner("fig08", "convergence time: WHAM vs ConfuciuX+ vs Spotlight+ (500 iters)");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let mut t = Table::new(["model", "wham", "confuciux+", "spotlight+", "cx+/wham", "sp+/wham"]);
    let mut cx_ratio = Vec::new();
    let mut sp_ratio = Vec::new();

    for name in wham::models::single_acc_models() {
        let graph = wham::models::training(name, Optimizer::Adam).unwrap();
        let batch = wham::models::info(name).unwrap().batch;

        let w = WhamSearch::new(&graph, batch, SearchOptions::default()).run(backend.as_mut());
        let cx = confuciux::run(
            &graph,
            batch,
            backend.as_mut(),
            confuciux::ConfuciuxOpts { iterations: 500, ..Default::default() },
        );
        let sp = spotlight::run(
            &graph,
            batch,
            backend.as_mut(),
            spotlight::SpotlightOpts { iterations: 500, ..Default::default() },
        );
        let rc = cx.wall.as_secs_f64() / w.wall.as_secs_f64();
        let rs = sp.wall.as_secs_f64() / w.wall.as_secs_f64();
        cx_ratio.push(rc);
        sp_ratio.push(rs);
        t.row([
            name.to_string(),
            format!("{:?}", w.wall),
            format!("{:?}", cx.wall),
            format!("{:?}", sp.wall),
            format!("{rc:.1}x"),
            format!("{rs:.1}x"),
        ]);
        assert!(rc > 1.0, "{name}: WHAM must converge faster than ConfuciuX+ ({rc:.2}x)");
        assert!(rs > 1.0, "{name}: WHAM must converge faster than Spotlight+ ({rs:.2}x)");
    }
    print!("{t}");
    println!(
        "# geomean speedup: vs ConfuciuX+ {:.1}x (paper 174x), vs Spotlight+ {:.1}x (paper 31x)",
        geomean(cx_ratio.iter().copied()),
        geomean(sp_ratio.iter().copied())
    );

    // ILP tractability: small graph converges optimally, language model
    // does not (the paper's 7-day N/A).
    let mut b = wham::graph::GraphBuilder::new();
    let a = b.gemm("a", 64, 64, 64, &[]);
    let x = b.gemm("x", 64, 64, 64, &[a]);
    let y = b.gemm("y", 64, 64, 64, &[a]);
    let _ = b.gemm("j", 64, 64, 64, &[x, y]);
    let small = b.finish();
    let ann = wham::cost::annotate::AnnotatedGraph::new(
        &small,
        wham::cost::Dims { tc_x: 64, tc_y: 64, vc_w: 64 },
        backend.as_mut(),
    );
    let ilp_small = wham::search::ilp::ilp_search(&ann, &Default::default(), 1_000_000);
    let bert = wham::models::training("bert-large", Optimizer::Adam).unwrap();
    let ann_l = wham::cost::annotate::AnnotatedGraph::new(
        &bert,
        wham::cost::Dims { tc_x: 128, tc_y: 128, vc_w: 128 },
        backend.as_mut(),
    );
    let ilp_large = wham::search::ilp::ilp_search(&ann_l, &Default::default(), 1_000_000);
    println!(
        "# ILP: small graph optimal={}, bert-large optimal={} (paper: N/A after 7 days)",
        ilp_small.optimal, ilp_large.optimal
    );
    assert!(ilp_small.optimal && !ilp_large.optimal);
    println!("\nfig08 OK");
}
