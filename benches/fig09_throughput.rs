//! Figure 9 — training throughput of WHAM-individual and WHAM-common vs
//! hand-optimized accelerators (TPUv2, NVDLA) and framework-suggested
//! designs (ConfuciuX+, Spotlight+), all normalized to ConfuciuX+ as in
//! the paper.
//!
//! Paper claims under test: WHAM-individual beats ConfuciuX+ (20x avg)
//! and Spotlight+ (12x avg); WHAM-common beats NVDLA (2x) and TPUv2
//! (12%); WHAM-individual adds ~3% over common (15% vs TPUv2).

use wham::arch::presets;
use wham::baselines::{confuciux, spotlight};
use wham::coordinator::{make_backend, BackendChoice};
use wham::graph::autodiff::Optimizer;
use wham::report::{geomean, speedup_table};
use wham::search::engine::{evaluate_design, SearchOptions, WhamSearch};
use wham::util::bench::banner;

fn main() {
    banner("fig09", "throughput vs baselines (normalized to ConfuciuX+)");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let models = wham::models::single_acc_models();

    // WHAM-common across the 8 workloads.
    let graphs: Vec<(String, wham::graph::OperatorGraph, u64)> = models
        .iter()
        .map(|n| {
            (
                n.to_string(),
                wham::models::training(n, Optimizer::Adam).unwrap(),
                wham::models::info(n).unwrap().batch,
            )
        })
        .collect();
    let workloads: Vec<wham::search::common::Workload> = graphs
        .iter()
        .map(|(n, g, b)| wham::search::common::Workload {
            name: n.clone(),
            graph: g,
            batch: *b,
            min_throughput: 0.0,
            weight: 1.0,
        })
        .collect();
    let common =
        wham::search::common::search_common(&workloads, SearchOptions::default(), backend.as_mut());
    println!("# WHAM-common config: {}", common.best.0.display());

    let mut rows = Vec::new();
    let mut ratios: Vec<[f64; 5]> = Vec::new();
    for (name, graph, batch) in &graphs {
        let cx = confuciux::run(
            graph,
            *batch,
            backend.as_mut(),
            confuciux::ConfuciuxOpts { iterations: 150, ..Default::default() },
        );
        let sp = spotlight::run(
            graph,
            *batch,
            backend.as_mut(),
            spotlight::SpotlightOpts { iterations: 150, ..Default::default() },
        );
        let nvdla = evaluate_design(graph, *batch, &presets::nvdla_scaled(), backend.as_mut());
        let tpu = evaluate_design(graph, *batch, &presets::tpuv2(), backend.as_mut());
        let wc = evaluate_design(graph, *batch, &common.best.0, backend.as_mut());
        let wi = WhamSearch::new(graph, *batch, SearchOptions::default()).run(backend.as_mut());

        let base = cx.eval.throughput;
        let vals = [
            sp.eval.throughput / base,
            nvdla.throughput / base,
            tpu.throughput / base,
            wc.throughput / base,
            wi.best.eval.throughput / base,
        ];
        ratios.push([
            wi.best.eval.throughput / cx.eval.throughput,
            wi.best.eval.throughput / sp.eval.throughput,
            wc.throughput / nvdla.throughput,
            wc.throughput / tpu.throughput,
            wi.best.eval.throughput / tpu.throughput,
        ]);
        rows.push((name.clone(), vals.to_vec()));
        // Per-model shape: WHAM-individual wins against every baseline.
        assert!(
            wi.best.eval.throughput >= cx.eval.throughput * 0.995
                && wi.best.eval.throughput >= sp.eval.throughput * 0.995,
            "{name}: WHAM-individual must match or beat the framework baselines \
             (wham {} vs cx {} / sp {})",
            wi.best.eval.throughput,
            cx.eval.throughput,
            sp.eval.throughput
        );
        assert!(
            wi.best.eval.throughput >= tpu.throughput * 0.999,
            "{name}: WHAM-individual must match or beat TPUv2"
        );
    }
    print!(
        "{}",
        speedup_table(&["spotlight+", "nvdla", "tpuv2", "wham-common", "wham-individual"], &rows)
    );
    let g = |i: usize| geomean(ratios.iter().map(|r| r[i]));
    println!("# geomean WHAM-individual / ConfuciuX+ : {:.2}x (paper 20x)", g(0));
    println!("# geomean WHAM-individual / Spotlight+ : {:.2}x (paper 12x)", g(1));
    println!("# geomean WHAM-common     / NVDLA      : {:.2}x (paper 2x)", g(2));
    println!("# geomean WHAM-common     / TPUv2      : {:.2}x (paper 1.12x)", g(3));
    println!("# geomean WHAM-individual / TPUv2      : {:.2}x (paper 1.15x)", g(4));
    assert!(g(0) > 1.0 && g(1) > 1.0 && g(3) > 1.0 && g(4) >= g(3) * 0.99);
    println!("\nfig09 OK");
}
