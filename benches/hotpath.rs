//! Hot-path micro-benchmarks for the performance pass (EXPERIMENTS.md
//! section Perf): cost annotation (native + PJRT), ASAP/ALAP, the greedy
//! list scheduler, the MCR loop, and a full per-workload search.

use wham::arch::Constraints;
use wham::coordinator::{make_backend, BackendChoice};
use wham::cost::annotate::AnnotatedGraph;
use wham::cost::Dims;
use wham::graph::autodiff::Optimizer;
use wham::search::engine::{SearchOptions, WhamSearch};
use wham::search::mcr::mcr;
use wham::sched::{asap_alap, greedy_schedule, CoreCount};
use wham::util::bench::{banner, bench};

fn main() {
    banner("hotpath", "L3 hot-path micro-benchmarks");
    let graph = wham::models::training("bert-large", Optimizer::Adam).unwrap();
    let d = Dims { tc_x: 128, tc_y: 128, vc_w: 128 };
    println!("workload: bert-large training graph, {} ops, {} edges", graph.len(), graph.num_edges());

    let mut native = make_backend(BackendChoice::Native).unwrap();
    println!(
        "{}",
        bench("annotate/native", 2, 20, || {
            std::hint::black_box(AnnotatedGraph::new(&graph, d, native.as_mut()));
        })
    );
    if let Ok(mut pjrt) = make_backend(BackendChoice::Pjrt) {
        println!(
            "{}",
            bench("annotate/pjrt (batched artifact call)", 2, 20, || {
                std::hint::black_box(AnnotatedGraph::new(&graph, d, pjrt.as_mut()));
            })
        );
    }

    let ann = AnnotatedGraph::new(&graph, d, native.as_mut());
    println!(
        "{}",
        bench("asap_alap", 2, 50, || {
            std::hint::black_box(asap_alap(&ann));
        })
    );
    let cp = asap_alap(&ann);
    println!(
        "{}",
        bench("greedy_schedule tc=4 vc=4", 2, 50, || {
            std::hint::black_box(greedy_schedule(&ann, &cp, CoreCount { tc: 4, vc: 4 }));
        })
    );
    println!(
        "{}",
        bench("mcr (full Algorithm 1)", 2, 20, || {
            std::hint::black_box(mcr(&ann, &Constraints::default()));
        })
    );
    println!(
        "{}",
        bench("wham_search/bert-large (end-to-end)", 1, 5, || {
            std::hint::black_box(
                WhamSearch::new(&graph, 8, SearchOptions::default()).run(native.as_mut()),
            );
        })
    );
    println!("\nhotpath OK");
}
