//! Hot-path micro-benchmarks for the performance pass (EXPERIMENTS.md
//! section Perf): cost annotation (interned vs naive, native + PJRT),
//! ASAP/ALAP, the greedy list scheduler, the MCR loop (galloping vs
//! one-at-a-time), and a full per-workload search (fast vs legacy
//! paths).
//!
//! Besides the human-readable report, writes `BENCH_hotpath.json` —
//! per-phase timings plus backend-row and scheduler-eval counts — so CI
//! can archive the bench trajectory (`--smoke` runs a fast variant with
//! the same schema; set `--out PATH` to redirect the artifact).

use wham::arch::Constraints;
use wham::coordinator::{make_backend, BackendChoice};
use wham::cost::annotate::AnnotatedGraph;
use wham::cost::Dims;
use wham::graph::autodiff::Optimizer;
use wham::search::engine::{SearchOptions, WhamSearch};
use wham::search::mcr::{mcr_with, mcr_with_scratch, GrowthMode, McrScratch};
use wham::sched::{asap_alap, greedy_schedule, CoreCount};
use wham::util::bench::{banner, bench, BenchStats};
use wham::util::json::{arr, Obj};

fn phase_json(s: &BenchStats) -> String {
    Obj::new()
        .str("name", &s.name)
        .u64("iters", s.iters as u64)
        .u64("median_ns", s.median.as_nanos() as u64)
        .u64("mean_ns", s.mean.as_nanos() as u64)
        .u64("min_ns", s.min.as_nanos() as u64)
        .finish()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke") || std::env::var("BENCH_SMOKE").is_ok();
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "BENCH_hotpath.json".to_string());
    let (warm, iters) = if smoke { (1, 3) } else { (2, 20) };
    let search_iters = if smoke { 1 } else { 5 };

    banner("hotpath", "L3 hot-path micro-benchmarks (fast vs legacy paths)");
    let graph = wham::models::training("bert-large", Optimizer::Adam).unwrap();
    let d = Dims { tc_x: 128, tc_y: 128, vc_w: 128 };
    let ops = graph.len() as u64;
    let classes = graph.cost_classes().len() as u64;
    let row_ratio = ops as f64 / classes as f64;
    println!(
        "workload: bert-large training graph, {} ops, {} edges",
        graph.len(),
        graph.num_edges()
    );
    println!(
        "cost-backend rows per dims evaluation: naive {ops} -> interned {classes} ({row_ratio:.1}x fewer)"
    );

    let mut phases: Vec<BenchStats> = Vec::new();
    let mut record = |s: BenchStats| {
        println!("{s}");
        phases.push(s);
    };

    let mut native = make_backend(BackendChoice::Native).unwrap();
    record(bench("annotate/native (interned classes)", warm, iters, || {
        std::hint::black_box(AnnotatedGraph::new(&graph, d, native.as_mut()));
    }));
    record(bench("annotate/native-naive (per-op rows)", warm, iters, || {
        std::hint::black_box(AnnotatedGraph::new_naive(&graph, d, native.as_mut()));
    }));
    if let Ok(mut pjrt) = make_backend(BackendChoice::Pjrt) {
        record(bench("annotate/pjrt (batched artifact call)", warm, iters, || {
            std::hint::black_box(AnnotatedGraph::new(&graph, d, pjrt.as_mut()));
        }));
    }

    let ann = AnnotatedGraph::new(&graph, d, native.as_mut());
    record(bench("asap_alap", warm, iters.max(10), || {
        std::hint::black_box(asap_alap(&ann));
    }));
    let cp = asap_alap(&ann);
    record(bench("greedy_schedule tc=4 vc=4", warm, iters.max(10), || {
        std::hint::black_box(greedy_schedule(&ann, &cp, CoreCount { tc: 4, vc: 4 }));
    }));
    record(bench("mcr/gallop (default)", warm, iters, || {
        std::hint::black_box(mcr_with(&ann, &Constraints::default(), GrowthMode::Gallop));
    }));
    record(bench("mcr/one-at-a-time (legacy)", warm, iters, || {
        std::hint::black_box(mcr_with(&ann, &Constraints::default(), GrowthMode::OneAtATime));
    }));

    // Incremental probe engine (checkpoint resume + bounded aborts) vs
    // the schedule-from-scratch parity oracle, same growth mode and same
    // probe sequence — isolates the cone-rescheduling win from the
    // gallop-vs-one-at-a-time eval-count win above. The scratch is
    // reused across iterations, matching the search engine's usage.
    let mut scratch = McrScratch::new();
    let inc_stats = bench("mcr/incremental (ckpt resume + bounds)", warm, iters, || {
        std::hint::black_box(mcr_with_scratch(
            &ann,
            &Constraints::default(),
            GrowthMode::Gallop,
            &mut scratch,
            false,
        ));
    });
    let full_stats = bench("mcr/full-reschedule (parity oracle)", warm, iters, || {
        std::hint::black_box(mcr_with_scratch(
            &ann,
            &Constraints::default(),
            GrowthMode::Gallop,
            &mut scratch,
            true,
        ));
    });
    let inc_mcr =
        mcr_with_scratch(&ann, &Constraints::default(), GrowthMode::Gallop, &mut scratch, false);
    let full_mcr =
        mcr_with_scratch(&ann, &Constraints::default(), GrowthMode::Gallop, &mut scratch, true);
    assert_eq!(
        (inc_mcr.cores, inc_mcr.schedule.makespan, inc_mcr.evals),
        (full_mcr.cores, full_mcr.schedule.makespan, full_mcr.evals),
        "incremental and full-reschedule probes must be bit-identical"
    );
    // The counter pair the CI regression guard tracks: probes/sec on
    // each engine. Both run the *same* probe sequence (evals are
    // engine-independent), so the ratio is the pure per-probe speedup.
    let probes_per_sec =
        |evals: usize, s: &BenchStats| evals as f64 / s.median.as_secs_f64().max(1e-12);
    let inc_rate = probes_per_sec(inc_mcr.evals, &inc_stats);
    let full_rate = probes_per_sec(full_mcr.evals, &full_stats);
    let inc_speedup = inc_rate / full_rate.max(1e-12);
    println!(
        "mcr probe rate: full-reschedule {full_rate:.0}/s -> incremental {inc_rate:.0}/s \
         ({inc_speedup:.1}x) at {} probes per run",
        inc_mcr.evals
    );
    record(inc_stats);
    record(full_stats);

    // Scheduler-eval accounting per MCR run — the Figure-8 cost unit the
    // galloping growth shrinks.
    let fast_mcr = mcr_with(&ann, &Constraints::default(), GrowthMode::Gallop);
    let slow_mcr = mcr_with(&ann, &Constraints::default(), GrowthMode::OneAtATime);
    assert_eq!(
        (fast_mcr.cores, fast_mcr.schedule.makespan),
        (slow_mcr.cores, slow_mcr.schedule.makespan),
        "gallop and one-at-a-time must land on the same design"
    );
    let mcr_ratio = slow_mcr.evals as f64 / fast_mcr.evals.max(1) as f64;
    println!(
        "mcr scheduler evals: one-at-a-time {} -> gallop {} ({mcr_ratio:.1}x fewer), cores {:?}",
        slow_mcr.evals, fast_mcr.evals, fast_mcr.cores
    );

    // End-to-end search: the fast default vs the legacy knobs.
    let fast_stats = bench("wham_search/bert-large (fast paths)", 1, search_iters, || {
        std::hint::black_box(
            WhamSearch::new(&graph, 8, SearchOptions::default()).run(native.as_mut()),
        );
    });
    let legacy_opts = SearchOptions {
        mcr_one_at_a_time: true,
        naive_annotation: true,
        ..Default::default()
    };
    let legacy_stats = bench("wham_search/bert-large (legacy paths)", 1, search_iters, || {
        std::hint::black_box(WhamSearch::new(&graph, 8, legacy_opts).run(native.as_mut()));
    });
    let oracle_opts = SearchOptions { full_reschedule: true, ..Default::default() };
    let oracle_stats = bench("wham_search/bert-large (full-resched oracle)", 1, search_iters, || {
        std::hint::black_box(WhamSearch::new(&graph, 8, oracle_opts).run(native.as_mut()));
    });
    let speedup = legacy_stats.median.as_secs_f64() / fast_stats.median.as_secs_f64().max(1e-12);
    println!("{fast_stats}");
    println!("{legacy_stats}");
    println!("{oracle_stats}");
    println!("end-to-end wham_search speedup: {speedup:.2}x (legacy median / fast median)");
    let fast_search = WhamSearch::new(&graph, 8, SearchOptions::default()).run(native.as_mut());
    let legacy_search = WhamSearch::new(&graph, 8, legacy_opts).run(native.as_mut());
    let oracle_search = WhamSearch::new(&graph, 8, oracle_opts).run(native.as_mut());
    assert_eq!(
        fast_search.best.config, legacy_search.best.config,
        "fast and legacy searches must find the same design"
    );
    assert_eq!(
        (fast_search.best.config, fast_search.scheduler_evals),
        (oracle_search.best.config, oracle_search.scheduler_evals),
        "incremental and full-reschedule searches must be bit-identical"
    );
    // The headline counter pair: whole-search scheduler evals/sec on the
    // incremental engine vs the full-reschedule oracle (identical probe
    // sequences, so the rate gap is the per-probe cost gap). The CI
    // regression guard fails on a >20% drop of the incremental rate vs
    // the committed bench-baselines/BENCH_hotpath.json.
    let search_rate = |evals: usize, s: &BenchStats| {
        evals as f64 / s.median.as_secs_f64().max(1e-12)
    };
    let evals_per_sec_incremental = search_rate(fast_search.scheduler_evals, &fast_stats);
    let evals_per_sec_full = search_rate(oracle_search.scheduler_evals, &oracle_stats);
    println!(
        "search scheduler evals/sec: full-reschedule {evals_per_sec_full:.0} -> \
         incremental {evals_per_sec_incremental:.0} \
         ({:.1}x)",
        evals_per_sec_incremental / evals_per_sec_full.max(1e-12)
    );
    phases.push(fast_stats);
    phases.push(legacy_stats);
    phases.push(oracle_stats);

    let json = Obj::new()
        .str("bench", "hotpath")
        .bool("smoke", smoke)
        .str("workload", "bert-large")
        .u64("ops", ops)
        .u64("cost_classes", classes)
        .u64("rows_per_dims_naive", ops)
        .u64("rows_per_dims_interned", classes)
        .f64("row_ratio", row_ratio)
        .raw(
            "mcr",
            &Obj::new()
                .u64("evals_gallop", fast_mcr.evals as u64)
                .u64("evals_one_at_a_time", slow_mcr.evals as u64)
                .f64("eval_ratio", mcr_ratio)
                .f64("probes_per_sec_incremental", inc_rate)
                .f64("probes_per_sec_full_resched", full_rate)
                .f64("incremental_speedup", inc_speedup)
                .finish(),
        )
        .raw(
            "search",
            &Obj::new()
                .f64("wall_ms_fast", fast_search.wall.as_secs_f64() * 1e3)
                .u64("scheduler_evals_fast", fast_search.scheduler_evals as u64)
                .u64("scheduler_evals_legacy", legacy_search.scheduler_evals as u64)
                .f64("speedup", speedup)
                .f64("evals_per_sec_incremental", evals_per_sec_incremental)
                .f64("evals_per_sec_full_resched", evals_per_sec_full)
                .finish(),
        )
        .raw("phases", &arr(phases.iter().map(phase_json)))
        .raw(
            "process",
            &Obj::new()
                .u64("backend_rows_total", wham::cost::backend_rows_total())
                .u64("scheduler_evals_total", wham::sched::evals_total())
                .finish(),
        )
        // Full registry snapshot (every `wham_*` counter this process
        // touched) so counter trajectories ride the bench artifact.
        .raw("metrics", &wham::telemetry::snapshot_json())
        .finish();
    std::fs::write(&out_path, &json).expect("writing bench artifact");
    println!("\nwrote {out_path}");
    println!("hotpath OK");
}
