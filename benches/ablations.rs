//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. pruner hysteresis (Algorithm 2's local-minimum escape);
//! 2. op-fusion on/off (the compiler optimization of section 6.2);
//! 3. scheduler priority: criticality vs FIFO (section 4.3's "the
//!    scheduler prioritizes critical operators");
//! 4. data-parallel scaling around a WHAM pipeline (section 5's
//!    "replicated pipeline").

use wham::coordinator::{make_backend, BackendChoice};
use wham::cost::annotate::AnnotatedGraph;
use wham::cost::Dims;
use wham::distributed::data_parallel::data_parallel;
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::pipeline::simulate;
use wham::distributed::Scheme;
use wham::graph::autodiff::{training_graph, Optimizer};
use wham::search::engine::{SearchOptions, WhamSearch};
use wham::sched::{asap_alap, greedy_schedule_with_priority, CoreCount, Priority};
use wham::util::bench::banner;

fn main() {
    banner("ablations", "design-choice ablations (hysteresis, fusion, priority, DP)");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();

    // ---- 1. hysteresis sweep --------------------------------------------
    println!("\n## pruner hysteresis (bert-large, throughput)");
    println!("hysteresis\tdims_evaluated\tbest_thpt");
    let g = wham::models::training("bert-large", Optimizer::Adam).unwrap();
    let mut best_h0 = 0.0;
    let mut best_h3 = 0.0;
    for h in [0u32, 1, 2, 3] {
        let opts = SearchOptions { hysteresis: h, ..Default::default() };
        let r = WhamSearch::new(&g, 8, opts).run(backend.as_mut());
        println!("{h}\t{}\t{:.3}", r.dims_evaluated, r.best.eval.throughput);
        if h == 0 {
            best_h0 = r.best.eval.throughput;
        }
        if h == 3 {
            best_h3 = r.best.eval.throughput;
        }
    }
    assert!(best_h3 >= best_h0 * 0.999, "more hysteresis must not lose quality");

    // ---- 2. fusion on/off -------------------------------------------------
    println!("\n## op-fusion (conv/GEMM + activation)");
    println!("model\tfused_pairs\tunfused_iter_ms\tfused_iter_ms\tspeedup");
    for name in ["vgg16", "resnet18", "bert-base"] {
        let fwd = wham::models::forward(name).unwrap();
        let (fused_fwd, pairs) = wham::graph::fusion::fuse(&fwd);
        let gu = training_graph(&fwd, Optimizer::Adam);
        let gf = training_graph(&fused_fwd, Optimizer::Adam);
        let batch = wham::models::info(name).unwrap().batch;
        let eu = wham::search::engine::evaluate_design(
            &gu, batch, &wham::arch::presets::tpuv2(), backend.as_mut());
        let ef = wham::search::engine::evaluate_design(
            &gf, batch, &wham::arch::presets::tpuv2(), backend.as_mut());
        println!(
            "{name}\t{pairs}\t{:.3}\t{:.3}\t{:.3}x",
            eu.seconds * 1e3,
            ef.seconds * 1e3,
            eu.seconds / ef.seconds
        );
        assert!(ef.seconds <= eu.seconds * 1.02, "{name}: fusion must not regress");
    }

    // ---- 3. scheduler priority ---------------------------------------------
    println!("\n## ready-queue priority (bert-large @ 128x128, tc=vc=3)");
    let ann = AnnotatedGraph::new(&g, Dims { tc_x: 128, tc_y: 128, vc_w: 128 }, backend.as_mut());
    let cp = asap_alap(&ann);
    let cores = CoreCount { tc: 3, vc: 3 };
    let crit = greedy_schedule_with_priority(&ann, &cp, cores, Priority::Criticality);
    let fifo = greedy_schedule_with_priority(&ann, &cp, cores, Priority::Fifo);
    println!("criticality\t{} cycles", crit.makespan);
    println!("fifo\t\t{} cycles", fifo.makespan);
    println!("# criticality/fifo = {:.4}", crit.makespan as f64 / fifo.makespan as f64);
    assert!(
        crit.makespan <= fifo.makespan,
        "criticality priority must not lose to FIFO on a branchy graph"
    );

    // ---- 4. data-parallel scaling ------------------------------------------
    println!("\n## data-parallel scaling (mini GPT2 pipeline x replicas)");
    println!("replicas\tthroughput\tefficiency");
    let mut cfg = wham::models::transformer_cfg("gpt2-xl").unwrap();
    cfg.layers = 8;
    let part = partition_transformer("mini", &cfg, 4, 1, Optimizer::Adam);
    let cfgs = vec![wham::arch::presets::tpuv2(); 4];
    let net = Network::default();
    let pipe = simulate(&part, &cfgs, Scheme::GPipe, &net, backend.as_mut());
    let base = data_parallel(&part, &pipe, 1, &net, 0.3).throughput;
    for r in [1u64, 2, 4, 8, 16] {
        let dp = data_parallel(&part, &pipe, r, &net, 0.3);
        let eff = dp.throughput / (base * r as f64);
        println!("{r}\t{:.3}/s\t{:.1}%", dp.throughput, eff * 100.0);
        assert!(eff <= 1.0 + 1e-9 && eff > 0.5);
    }

    println!("\nablations OK");
}
