//! Cold vs warm `/search` latency through the mining service — the
//! cache-effectiveness number future scaling PRs track. Measures three
//! layers: the engine against a warm in-process design database, the
//! full HTTP round trip against a warm server, and request coalescing
//! under concurrent identical load.

use std::net::TcpListener;

use wham::coordinator::BackendChoice;
use wham::graph::autodiff::Optimizer;
use wham::graph::fingerprint;
use wham::search::engine::{SearchOptions, WhamSearch};
use wham::service::cache::{context_key, DesignDb};
use wham::service::http::request;
use wham::service::{start, ServeOptions};
use wham::util::bench::{banner, bench, time_once};

fn main() {
    banner("service_cache", "design-database effectiveness: cold vs warm /search");
    let model = "bert-base";
    let graph = wham::models::training(model, Optimizer::Adam).unwrap();
    let batch = wham::models::info(model).unwrap().batch;
    let opts = SearchOptions::default();

    // ---- engine-level: run_cached against the shared database ----------
    let db = DesignDb::in_memory();
    let ctx = context_key(fingerprint(&graph), batch, &opts, "native");
    let search = WhamSearch::new(&graph, batch, opts);
    let (cold, cold_wall) = time_once(|| {
        search.run_cached(&mut wham::cost::native::NativeCost, &mut db.scoped(ctx))
    });
    println!(
        "engine/cold: {:>12?}  ({} scheduler evals, {} dims)",
        cold_wall, cold.scheduler_evals, cold.dims_evaluated
    );
    println!(
        "{}",
        bench("engine/warm (db hit, 0 scheduler evals)", 1, 20, || {
            let r = search.run_cached(&mut wham::cost::native::NativeCost, &mut db.scoped(ctx));
            assert_eq!(r.scheduler_evals, 0);
            std::hint::black_box(r);
        })
    );

    // ---- HTTP round trip -----------------------------------------------
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let h = start(
        listener,
        ServeOptions {
            workers: 8,
            db_path: None,
            backend: BackendChoice::Native,
            ..Default::default()
        },
    )
    .unwrap();
    let body = format!("{{\"model\":\"{model}\"}}");
    let (_, http_cold) = time_once(|| {
        let (status, _) = request(h.addr, "POST", "/search", Some(&body)).unwrap();
        assert_eq!(status, 200);
    });
    println!("http/cold  : {http_cold:>12?}  (one full search + round trip)");
    println!(
        "{}",
        bench("http/warm /search round trip", 2, 30, || {
            let (status, resp) = request(h.addr, "POST", "/search", Some(&body)).unwrap();
            assert_eq!(status, 200);
            std::hint::black_box(resp);
        })
    );

    // ---- coalescing under concurrent identical load --------------------
    let (_, burst) = time_once(|| {
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let addr = h.addr;
                let body = body.clone();
                std::thread::spawn(move || request(addr, "POST", "/search", Some(&body)).unwrap())
            })
            .collect();
        for t in threads {
            let (status, _) = t.join().unwrap();
            assert_eq!(status, 200);
        }
    });
    println!("http/burst : {burst:>12?}  (8 concurrent identical requests, warm)");
    println!(
        "series: cold_ms={:.2} warm_db_entries={} ",
        cold_wall.as_secs_f64() * 1e3,
        db.len()
    );
}
