//! Figure 10 — Perf/TDP of WHAM designs optimized for Perf/TDP with the
//! TPUv2 throughput as the floor, normalized to TPUv2.
//!
//! Paper claims under test: WHAM-common ~19% better Perf/TDP than TPUv2;
//! WHAM-individual matches or beats common; both maintain the floor.

use wham::arch::presets;
use wham::coordinator::{make_backend, BackendChoice};
use wham::graph::autodiff::Optimizer;
use wham::metrics::Metric;
use wham::report::{geomean, speedup_table};
use wham::search::engine::{evaluate_design, SearchOptions, WhamSearch};
use wham::util::bench::banner;

fn main() {
    banner("fig10", "Perf/TDP vs TPUv2 (TPUv2 throughput floor)");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let models = wham::models::single_acc_models();

    let graphs: Vec<(String, wham::graph::OperatorGraph, u64)> = models
        .iter()
        .map(|n| {
            (
                n.to_string(),
                wham::models::training(n, Optimizer::Adam).unwrap(),
                wham::models::info(n).unwrap().batch,
            )
        })
        .collect();

    // Common design under the Perf/TDP metric with per-model floors.
    let workloads: Vec<wham::search::common::Workload> = graphs
        .iter()
        .map(|(n, g, b)| {
            let floor = evaluate_design(g, *b, &presets::tpuv2(), backend.as_mut()).throughput;
            wham::search::common::Workload {
                name: n.clone(),
                graph: g,
                batch: *b,
                min_throughput: floor,
                weight: 1.0,
            }
        })
        .collect();
    let copts = SearchOptions { metric: Metric::PerfPerTdp, ..Default::default() };
    let common = wham::search::common::search_common(&workloads, copts, backend.as_mut());
    println!("# WHAM-common config: {}", common.best.0.display());

    let mut rows = Vec::new();
    let mut rc = Vec::new();
    let mut ri = Vec::new();
    for (name, graph, batch) in &graphs {
        let tpu = evaluate_design(graph, *batch, &presets::tpuv2(), backend.as_mut());
        let wc = evaluate_design(graph, *batch, &common.best.0, backend.as_mut());
        let iopts = SearchOptions {
            metric: Metric::PerfPerTdp,
            min_throughput: tpu.throughput,
            ..Default::default()
        };
        let wi = WhamSearch::new(graph, *batch, iopts).run(backend.as_mut());
        rows.push((
            name.clone(),
            vec![wc.perf_per_tdp / tpu.perf_per_tdp, wi.best.eval.perf_per_tdp / tpu.perf_per_tdp],
        ));
        rc.push(wc.perf_per_tdp / tpu.perf_per_tdp);
        ri.push(wi.best.eval.perf_per_tdp / tpu.perf_per_tdp);
        assert!(
            wi.best.eval.throughput >= tpu.throughput * 0.99,
            "{name}: throughput floor violated"
        );
        assert!(
            wi.best.eval.perf_per_tdp >= tpu.perf_per_tdp * 0.999,
            "{name}: WHAM-individual must not lose Perf/TDP to TPUv2"
        );
    }
    print!("{}", speedup_table(&["wham-common/tpuv2", "wham-individual/tpuv2"], &rows));
    println!("# geomean WHAM-common/TPUv2     : {:.2}x (paper 1.19x)", geomean(rc.iter().copied()));
    println!("# geomean WHAM-individual/TPUv2 : {:.2}x", geomean(ri.iter().copied()));
    assert!(geomean(ri.iter().copied()) >= 1.0);
    println!("\nfig10 OK");
}
