//! Figure 11 — pipeline-parallel training throughput of WHAM-common /
//! -individual / -mosaic vs a TPUv2 pipeline; depth 32, GPipe,
//! activation stashing.
//!
//! Paper claims under test: Common ~17%, Individual ~22%, Mosaic ~23%
//! over TPUv2 on average; Individual >= Common; Mosaic's heterogeneity
//! adds only modest gains over Individual (repeated transformer layers).

use wham::arch::presets;
use wham::coordinator::{make_backend, BackendChoice};
use wham::distributed::global_search::{global_search, GlobalOptions};
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::pipeline::simulate;
use wham::distributed::Scheme;
use wham::graph::autodiff::Optimizer;
use wham::report::geomean;
use wham::util::bench::banner;
use wham::util::table::Table;

fn main() {
    banner("fig11", "pipeline throughput vs TPUv2 (depth 32, GPipe)");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let net = Network::default();
    let models: Vec<_> = ["opt-1.3b", "gpt2-xl"]
        .iter()
        .map(|n| {
            let cfg = wham::models::transformer_cfg(n).unwrap();
            partition_transformer(n, &cfg, 32, 1, Optimizer::Adam)
        })
        .collect();

    let r = global_search(&models, &GlobalOptions::default(), &net, backend.as_mut());
    let mut t = Table::new(["model", "tpuv2 thpt", "common", "individual", "mosaic"]);
    let mut rc = Vec::new();
    let mut rind = Vec::new();
    let mut rm = Vec::new();
    for (i, part) in models.iter().enumerate() {
        let cfgs = vec![presets::tpuv2(); part.stages.len()];
        let tpu = simulate(part, &cfgs, Scheme::GPipe, &net, backend.as_mut());
        let c = r.common.1[i].eval.throughput / tpu.throughput;
        let ind = r.individual[i].eval.throughput / tpu.throughput;
        let m = r.mosaic[i].eval.throughput / tpu.throughput;
        rc.push(c);
        rind.push(ind);
        rm.push(m);
        t.row([
            part.name.clone(),
            format!("{:.3}/s", tpu.throughput),
            format!("{c:.3}x"),
            format!("{ind:.3}x"),
            format!("{m:.3}x"),
        ]);
        assert!(ind >= c * 0.999, "{}: individual must be >= common", part.name);
        assert!(ind > 1.0, "{}: individual must beat the TPUv2 pipeline", part.name);
    }
    print!("{t}");
    println!(
        "# geomean vs TPUv2: common {:.3}x (paper 1.17x), individual {:.3}x (paper 1.22x), mosaic {:.3}x (paper 1.23x)",
        geomean(rc.iter().copied()),
        geomean(rind.iter().copied()),
        geomean(rm.iter().copied())
    );
    println!("\nfig11 OK");
}
