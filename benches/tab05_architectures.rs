//! Table 5 — per-workload architectures chosen by each framework,
//! throughput-optimized. Paper reference column included; our substrate's
//! cost model favours somewhat larger tiles (DESIGN.md substitutions),
//! so configurations match in *shape* (multi-core, constraint-bound)
//! rather than verbatim.

use wham::baselines::{confuciux, spotlight};
use wham::coordinator::{make_backend, BackendChoice};
use wham::graph::autodiff::Optimizer;
use wham::search::engine::{SearchOptions, WhamSearch};
use wham::util::bench::banner;
use wham::util::table::Table;

const PAPER_WHAM: &[(&str, &str)] = &[
    ("mobilenet_v3", "<1, 256x128, 1, 256>"),
    ("resnet18", "<2, 128x64, 2, 128>"),
    ("inception_v3", "<4, 128x64, 4, 128>"),
    ("resnext101", "<2, 128x64, 2, 128>"),
    ("vgg16", "<1, 256x128, 1, 256>"),
    ("gnmt4", "<3, 128x64, 3, 128>"),
    ("bert-base", "<3, 128x64, 3, 128>"),
    ("bert-large", "<3, 128x64, 3, 128>"),
];

fn main() {
    banner("tab05", "per-accelerator architectures (throughput-optimized)");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let mut t = Table::new(["model", "confuciux+", "spotlight+", "wham-individual", "paper wham"]);
    for (name, paper) in PAPER_WHAM {
        let graph = wham::models::training(name, Optimizer::Adam).unwrap();
        let batch = wham::models::info(name).unwrap().batch;
        let w = WhamSearch::new(&graph, batch, SearchOptions::default()).run(backend.as_mut());
        let cx = confuciux::run(
            &graph,
            batch,
            backend.as_mut(),
            confuciux::ConfuciuxOpts { iterations: 150, ..Default::default() },
        );
        let sp = spotlight::run(
            &graph,
            batch,
            backend.as_mut(),
            spotlight::SpotlightOpts { iterations: 150, ..Default::default() },
        );
        assert!(w.best.config.in_template());
        t.row([
            name.to_string(),
            cx.config.display(),
            sp.config.display(),
            w.best.config.display(),
            paper.to_string(),
        ]);
    }
    print!("{t}");
    println!("\ntab05 OK");
}
