//! Figure 2 — per-layer tensor/vector core utilization of Inception_v3 on
//! a single `<1, 256x256, 1, 256>` design (the NVDLA-scaled corner).
//!
//! Reproduces the paper's observation: "numerous workloads fail to fully
//! utilize the 256x256 systolic array ... layers with fewer channels have
//! lower utilization" (y-axis capped at 50% in the paper).

use wham::coordinator::{make_backend, BackendChoice};
use wham::cost::annotate::AnnotatedGraph;
use wham::cost::Dims;
use wham::graph::CoreType;
use wham::util::bench::banner;

fn main() {
    banner("fig02", "per-layer utilization, Inception_v3 on <1, 256x256, 1, 256>");
    let graph = wham::models::forward("inception_v3").unwrap();
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let ann =
        AnnotatedGraph::new(&graph, Dims { tc_x: 256, tc_y: 256, vc_w: 256 }, backend.as_mut());

    println!("layer\tcore\tutil_pct");
    let mut low_util_layers = 0usize;
    let mut tensor_ops = 0usize;
    for (i, op) in graph.ops.iter().enumerate() {
        let core = match ann.core[i] {
            CoreType::Tensor | CoreType::Fused => "tensor",
            CoreType::Vector => "vector",
        };
        let u = ann.costs[i].util * 100.0;
        println!("{}\t{}\t{:.2}", op.name, core, u);
        if ann.core[i] == CoreType::Tensor {
            tensor_ops += 1;
            if u < 50.0 {
                low_util_layers += 1;
            }
        }
    }
    let mean_t = ann.mean_util(CoreType::Tensor) * 100.0;
    let mean_v = ann.mean_util(CoreType::Vector) * 100.0;
    println!("# mean tensor util {mean_t:.1}%  mean vector util {mean_v:.1}%");
    println!(
        "# {low_util_layers}/{tensor_ops} tensor layers below 50% utilization (paper caps the y-axis at 50%)"
    );
    assert!(
        low_util_layers * 3 >= tensor_ops,
        "expected a large fraction of Inception layers to underutilize a 256x256 array"
    );
    assert!(mean_t < 85.0, "mean tensor utilization should be far from full");
    println!("\nfig02 OK");
}
