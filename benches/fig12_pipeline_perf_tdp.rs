//! Figure 12 — pipeline-parallel Perf/TDP of WHAM families vs the TPUv2
//! pipeline, optimized for Perf/TDP with the TPUv2 pipeline throughput as
//! the floor; depth 32, GPipe.
//!
//! Paper claims under test: Common ~1.6x, Individual ~8.1x, Mosaic ~2.0x
//! over TPUv2; Mosaic may trail Individual (per-stage top-1 overspends
//! area on non-bottleneck stages).

use wham::arch::presets;
use wham::coordinator::{make_backend, BackendChoice};
use wham::distributed::global_search::{global_search, GlobalOptions};
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::pipeline::simulate;
use wham::distributed::Scheme;
use wham::graph::autodiff::Optimizer;
use wham::metrics::Metric;
use wham::report::geomean;
use wham::util::bench::banner;
use wham::util::table::Table;

fn main() {
    banner("fig12", "pipeline Perf/TDP vs TPUv2 (depth 32, GPipe, floor=TPUv2)");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let net = Network::default();
    let models: Vec<_> = ["opt-1.3b", "gpt2-xl"]
        .iter()
        .map(|n| {
            let cfg = wham::models::transformer_cfg(n).unwrap();
            partition_transformer(n, &cfg, 32, 1, Optimizer::Adam)
        })
        .collect();

    // TPUv2 pipeline floor (min across models, as the CLI does).
    let mut floor = f64::INFINITY;
    let mut tpu_evals = Vec::new();
    for part in &models {
        let cfgs = vec![presets::tpuv2(); part.stages.len()];
        let e = simulate(part, &cfgs, Scheme::GPipe, &net, backend.as_mut());
        floor = floor.min(e.throughput);
        tpu_evals.push(e);
    }
    let opts = GlobalOptions {
        metric: Metric::PerfPerTdp,
        min_throughput: floor,
        ..Default::default()
    };
    let r = global_search(&models, &opts, &net, backend.as_mut());

    let mut t = Table::new(["model", "tpuv2 perf/TDP", "common", "individual", "mosaic"]);
    let mut rc = Vec::new();
    let mut ri = Vec::new();
    let mut rm = Vec::new();
    for (i, part) in models.iter().enumerate() {
        let tpu = &tpu_evals[i];
        let c = r.common.1[i].eval.perf_per_tdp / tpu.perf_per_tdp;
        let ind = r.individual[i].eval.perf_per_tdp / tpu.perf_per_tdp;
        let m = r.mosaic[i].eval.perf_per_tdp / tpu.perf_per_tdp;
        rc.push(c);
        ri.push(ind);
        rm.push(m);
        t.row([
            part.name.clone(),
            format!("{:.5}", tpu.perf_per_tdp),
            format!("{c:.3}x"),
            format!("{ind:.3}x"),
            format!("{m:.3}x"),
        ]);
        assert!(ind >= 1.0, "{}: individual Perf/TDP must beat the TPUv2 pipeline", part.name);
        assert!(ind >= c * 0.999, "{}: individual >= common", part.name);
    }
    print!("{t}");
    println!(
        "# geomean vs TPUv2: common {:.2}x (paper 1.6x), individual {:.2}x (paper 8.1x), mosaic {:.2}x (paper 2.0x)",
        geomean(rc.iter().copied()),
        geomean(ri.iter().copied()),
        geomean(rm.iter().copied())
    );
    println!("\nfig12 OK");
}
