//! Figure 7 — convergence time of the global distributed search, pruned
//! (top-level tree pruner, section 5.1) vs unpruned (every candidate in
//! the k x s x m pool), pipeline depth 32, k = 10.
//!
//! Paper claim under test: the pruned search converges ~2.5x faster than
//! the unpruned search while finding the same (or better) designs.

use wham::coordinator::{make_backend, BackendChoice};
use wham::distributed::global_search::{global_search, GlobalOptions};
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::graph::autodiff::Optimizer;
use wham::util::bench::banner;

fn main() {
    banner("fig07", "global-search convergence: pruned vs unpruned (depth 32, k=10)");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let net = Network::default();
    let models: Vec<_> = ["opt-1.3b", "gpt2-xl"]
        .iter()
        .map(|n| {
            let cfg = wham::models::transformer_cfg(n).unwrap();
            partition_transformer(n, &cfg, 32, 1, Optimizer::Adam)
        })
        .collect();

    let pruned_opts = GlobalOptions { top_k: 10, ..Default::default() };
    let t0 = std::time::Instant::now();
    let pruned = global_search(&models, &pruned_opts, &net, backend.as_mut());
    let pruned_wall = t0.elapsed();

    let unpruned_opts = GlobalOptions { top_k: 10, no_prune: true, ..Default::default() };
    let t1 = std::time::Instant::now();
    let unpruned = global_search(&models, &unpruned_opts, &net, backend.as_mut());
    let unpruned_wall = t1.elapsed();

    println!("arm\twall\tcandidates_evaluated\tpool");
    println!("pruned\t{pruned_wall:?}\t{}\t{}", pruned.candidates_evaluated, pruned.candidate_pool);
    println!(
        "unpruned\t{unpruned_wall:?}\t{}\t{}",
        unpruned.candidates_evaluated, unpruned.candidate_pool
    );
    let speedup = unpruned_wall.as_secs_f64() / pruned_wall.as_secs_f64();
    println!("# pruned speedup: {speedup:.2}x (paper: 2.5x)");

    // Quality equivalence: the pruner must not lose the winners.
    for (p, u) in pruned.individual.iter().zip(&unpruned.individual) {
        let rel = p.eval.throughput / u.eval.throughput;
        println!("# {}: pruned/unpruned individual throughput = {rel:.4}", p.model);
        assert!(rel > 0.97, "{}: pruner lost a winning design", p.model);
    }
    assert!(
        pruned.candidates_evaluated <= unpruned.candidates_evaluated,
        "pruned arm must evaluate no more candidates"
    );
    println!("\nfig07 OK");
}
