//! Figure 1 — design-space exploration scatter: iteration latency vs
//! Perf/TDP for every design point WHAM explores on Inception_v3 and
//! BERT-Large, against ConfuciuX+/Spotlight+ picks and the TPUv2 design.
//!
//! Regenerates the paper's qualitative claims: the throughput-optimized
//! WHAM point minimizes latency; the Perf/TDP-optimized point maximizes
//! efficiency while holding the TPUv2 throughput floor; inference-era
//! searchers land far from both frontiers.

use wham::arch::presets;
use wham::baselines::{confuciux, spotlight};
use wham::coordinator::{make_backend, BackendChoice};
use wham::graph::autodiff::Optimizer;
use wham::metrics::Metric;
use wham::search::engine::{evaluate_design, SearchOptions, WhamSearch};
use wham::util::bench::banner;

fn main() {
    banner("fig01", "DSE scatter: latency vs Perf/TDP (Inception_v3, BERT-Large)");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();

    for model in ["inception_v3", "bert-large"] {
        let graph = wham::models::training(model, Optimizer::Adam).unwrap();
        let batch = wham::models::info(model).unwrap().batch;
        println!("\n## {model} ({} ops)", graph.len());
        println!("point\tconfig\tlatency_ms\tperf_per_tdp");

        let tpu = evaluate_design(&graph, batch, &presets::tpuv2(), backend.as_mut());
        println!("tpuv2\t{}\t{:.3}\t{:.4}", presets::tpuv2(), tpu.seconds * 1e3, tpu.perf_per_tdp);

        // WHAM optimized for throughput: scatter of every explored point.
        let thpt = WhamSearch::new(&graph, batch, SearchOptions::default()).run(backend.as_mut());
        for p in &thpt.explored {
            println!(
                "wham-explored\t{}\t{:.3}\t{:.4}",
                p.config,
                p.eval.seconds * 1e3,
                p.eval.perf_per_tdp
            );
        }
        let bt = &thpt.best;
        println!("wham-thpt\t{}\t{:.3}\t{:.4}", bt.config, bt.eval.seconds * 1e3, bt.eval.perf_per_tdp);

        // WHAM optimized for Perf/TDP with the TPUv2 throughput floor.
        let eff_opts = SearchOptions {
            metric: Metric::PerfPerTdp,
            min_throughput: tpu.throughput,
            ..Default::default()
        };
        let eff = WhamSearch::new(&graph, batch, eff_opts).run(backend.as_mut());
        let be = &eff.best;
        println!("wham-perf/tdp\t{}\t{:.3}\t{:.4}", be.config, be.eval.seconds * 1e3, be.eval.perf_per_tdp);

        // Inference-era searchers (training-extended), shortened budget.
        let cx = confuciux::run(
            &graph,
            batch,
            backend.as_mut(),
            confuciux::ConfuciuxOpts { iterations: 120, ..Default::default() },
        );
        println!("confuciux+\t{}\t{:.3}\t{:.4}", cx.config, cx.eval.seconds * 1e3, cx.eval.perf_per_tdp);
        let sp = spotlight::run(
            &graph,
            batch,
            backend.as_mut(),
            spotlight::SpotlightOpts { iterations: 120, ..Default::default() },
        );
        println!("spotlight+\t{}\t{:.3}\t{:.4}", sp.config, sp.eval.seconds * 1e3, sp.eval.perf_per_tdp);

        // Shape assertions (the paper's qualitative reading of Fig. 1).
        assert!(bt.eval.seconds <= tpu.seconds, "WHAM-thpt must minimize latency vs TPUv2");
        assert!(
            be.eval.perf_per_tdp >= tpu.perf_per_tdp * 0.999,
            "WHAM-perf/tdp must beat the TPUv2 efficiency point"
        );
        assert!(be.eval.throughput >= tpu.throughput * 0.99, "floor must hold");
        println!(
            "# summary: wham-thpt latency {:.3} ms vs tpu {:.3} ms; wham eff {:.4} vs tpu {:.4}",
            bt.eval.seconds * 1e3,
            tpu.seconds * 1e3,
            be.eval.perf_per_tdp,
            tpu.perf_per_tdp
        );
    }
    println!("\nfig01 OK");
}
