//! Figure 13 — GPT3 throughput across tensor-model-parallel x pipeline
//! configurations on 64 devices (TMP 1 -> 8, PP 64 -> 8), WHAM designs
//! vs TPUv2.
//!
//! Paper claims under test: WHAM ~2x over TPUv2 at TMP=8/PP=8; WHAM
//! individual == mosaic for GPT3 (uniform stages).

use wham::arch::presets;
use wham::coordinator::{make_backend, BackendChoice};
use wham::distributed::global_search::{global_search, GlobalOptions};
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::pipeline::simulate;
use wham::distributed::Scheme;
use wham::graph::autodiff::Optimizer;
use wham::util::bench::banner;
use wham::util::table::Table;

fn main() {
    banner("fig13", "GPT3: TMP x PP sweep on 64 devices, WHAM vs TPUv2");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let net = Network::default();
    let cfg = wham::models::transformer_cfg("gpt3").unwrap();
    const DEVICES: u64 = 64;

    let mut t = Table::new(["tmp", "pp", "tpuv2 thpt", "wham thpt", "wham/tpuv2", "stage fits HBM"]);
    let mut best_ratio: f64 = 0.0;
    for tmp in [1u64, 2, 4, 8] {
        let pp = DEVICES / tmp;
        let part = partition_transformer("gpt3", &cfg, pp, tmp, Optimizer::Adam);
        let cfgs = vec![presets::tpuv2(); part.stages.len()];
        let tpu = simulate(&part, &cfgs, Scheme::GPipe, &net, backend.as_mut());
        let r = global_search(
            std::slice::from_ref(&part),
            &GlobalOptions::default(),
            &net,
            backend.as_mut(),
        );
        let wham = &r.individual[0];
        let ratio = wham.eval.throughput / tpu.throughput;
        best_ratio = best_ratio.max(ratio);
        // GPT3 stages are uniform: individual and mosaic coincide.
        let mosaic = &r.mosaic[0];
        let same = (mosaic.eval.throughput / wham.eval.throughput - 1.0).abs() < 0.05;
        let fits = part
            .stages
            .iter()
            .all(|s| s.fits_hbm(wham::distributed::Scheme::GPipe, part.num_micro, pp));
        t.row([
            tmp.to_string(),
            pp.to_string(),
            format!("{:.4}/s", tpu.throughput),
            format!("{:.4}/s", wham.eval.throughput),
            format!("{ratio:.3}x"),
            fits.to_string(),
        ]);
        assert!(ratio >= 1.0, "WHAM must not lose to TPUv2 at tmp={tmp}");
        assert!(same, "GPT3 stages are uniform -> individual ~= mosaic");
    }
    print!("{t}");
    println!("# best WHAM/TPUv2 across configs: {best_ratio:.2}x (paper: 2x at TMP=8)");
    println!("\nfig13 OK");
}
