//! Figure 14 — top-k hyper-parameter sweep: Perf/TDP of WHAM-common for
//! distributed pipeline training as k grows, normalized to TPUv2.
//!
//! Paper claims under test: top-1 is not always best; improvements
//! saturate by k ~= 10 (diminishing returns).

use wham::arch::presets;
use wham::coordinator::{make_backend, BackendChoice};
use wham::distributed::global_search::{global_search, GlobalOptions};
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::pipeline::simulate;
use wham::distributed::Scheme;
use wham::graph::autodiff::Optimizer;
use wham::metrics::Metric;
use wham::report::geomean;
use wham::util::bench::banner;

fn main() {
    banner("fig14", "top-k sweep: WHAM-common Perf/TDP vs TPUv2 (3 LLMs)");
    let mut backend = make_backend(BackendChoice::Auto).unwrap();
    let net = Network::default();
    // GPT3 at 64 devices (tmp 8 x pp 8), others at depth 32.
    let models: Vec<_> = vec![
        partition_transformer("opt-1.3b", &wham::models::transformer_cfg("opt-1.3b").unwrap(), 32, 1, Optimizer::Adam),
        partition_transformer("gpt2-xl", &wham::models::transformer_cfg("gpt2-xl").unwrap(), 32, 1, Optimizer::Adam),
        partition_transformer("gpt3", &wham::models::transformer_cfg("gpt3").unwrap(), 8, 8, Optimizer::Adam),
    ];
    let mut floor = f64::INFINITY;
    let mut tpu = Vec::new();
    for part in &models {
        let cfgs = vec![presets::tpuv2(); part.stages.len()];
        let e = simulate(part, &cfgs, Scheme::GPipe, &net, backend.as_mut());
        floor = floor.min(e.throughput);
        tpu.push(e);
    }

    println!("k\tgeomean perf/TDP vs TPUv2\tcandidates evaluated");
    let mut series = Vec::new();
    for k in [1usize, 2, 5, 10, 15] {
        let opts = GlobalOptions {
            metric: Metric::PerfPerTdp,
            min_throughput: floor,
            top_k: k,
            ..Default::default()
        };
        let r = global_search(&models, &opts, &net, backend.as_mut());
        let g = geomean(
            r.common
                .1
                .iter()
                .zip(&tpu)
                .map(|(m, t)| m.eval.perf_per_tdp / t.perf_per_tdp),
        );
        println!("{k}\t{g:.4}x\t{}", r.candidates_evaluated);
        series.push((k, g));
    }
    // Saturation: k=10 within a few percent of k=15, and >= k=1.
    let at = |k: usize| series.iter().find(|(kk, _)| *kk == k).unwrap().1;
    assert!(at(10) >= at(1) * 0.999, "k=10 must not lose to top-1");
    assert!((at(15) - at(10)).abs() / at(10) < 0.05, "gains must saturate after k~10");
    println!("# saturation confirmed: k=10 -> {:.4}x, k=15 -> {:.4}x", at(10), at(15));
    println!("\nfig14 OK");
}
